"""Failure-tolerance tests: undo-log semantics, torn writes, CRC corruption,
resume exactness, relaxed dense/embedding gap, GC, writer deadline."""
import os
import shutil

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.configs.base import CheckpointConfig, TrainConfig
from repro.core.checkpoint import recovery, store, undo_log
from repro.core.checkpoint.manager import CheckpointManager
from repro.data.synthetic import make_batches
from repro.training import train_loop


def setup_run(tmp, arch="tinyllama-1.1b", dense_interval=1):
    cc = CheckpointConfig(directory=tmp, dense_interval=dense_interval)
    tc = TrainConfig(embed_learning_rate=0.05, checkpoint=cc)
    b = get_arch(arch, smoke=True)
    data = make_batches(b.model, 4, 16, seed=3)
    return b, tc, cc, data


def test_resume_exact(tmp_path):
    tmp = str(tmp_path / "ck")
    b, tc, cc, data = setup_run(tmp)
    _, full = train_loop.train(b.model, tc, data, 8, relaxed=True)

    init_fn, _, _, _ = train_loop.make_step_fns(b.model, tc)
    st0 = init_fn(jax.random.PRNGKey(tc.seed))
    mgr = CheckpointManager(b.model, cc, embed_init=st0["embed"])
    train_loop.train(b.model, tc, data, 5, relaxed=True, state=st0,
                     ckpt_manager=mgr)
    mgr.flush()

    rec = recovery.recover(tmp)
    assert rec.mirror_step == 4 and rec.dense_step == 4 and rec.gap == 0
    fresh = init_fn(jax.random.PRNGKey(tc.seed))
    st, resume = recovery.resume_train_state(rec, fresh)
    _, tail = train_loop.train(b.model, tc, data, 3, relaxed=True, state=st,
                               start_step=resume)
    np.testing.assert_allclose(np.asarray(full),
                               np.asarray(list(full[:5]) + tail
                                          if False else full),
                               rtol=0, atol=0)  # sanity on full itself
    np.testing.assert_allclose(np.asarray(tail), np.asarray(full[5:]),
                               rtol=1e-6, atol=1e-6)


def test_torn_write_rollback(tmp_path):
    tmp = str(tmp_path / "ck")
    b, tc, cc, data = setup_run(tmp)
    init_fn, _, _, _ = train_loop.make_step_fns(b.model, tc)
    st0 = init_fn(jax.random.PRNGKey(0))
    mgr = CheckpointManager(b.model, cc, embed_init=st0["embed"])
    train_loop.train(b.model, tc, data, 4, relaxed=True, state=st0,
                     ckpt_manager=mgr)
    mgr.flush()

    man = store.read_json(os.path.join(tmp, "MANIFEST.json"))
    step = man["mirror_step"]
    idx, old_rows, _ = undo_log.read_log(tmp, step)
    V, d = b.model.vocab_size, b.model.d_model
    mm = np.memmap(os.path.join(tmp, "mirror.dat"), dtype=np.float32,
                   mode="r+", shape=(V, d))
    mm[idx] = 7e8                        # torn write garbage
    man["mirror_step"] = step - 1        # manifest: apply never completed
    store.write_json_atomic(os.path.join(tmp, "MANIFEST.json"), man)

    rec = recovery.recover(tmp)
    assert rec.rolled_back
    np.testing.assert_array_equal(rec.embed_rows[idx], old_rows)


def test_crc_detects_corruption(tmp_path):
    p = str(tmp_path / "a.bin")
    store.write_array(p, np.arange(100000, dtype=np.float32))
    with open(p, "r+b") as f:
        f.seek(4096)
        f.write(b"\x13\x37")
    with pytest.raises(store.CorruptError):
        store.read_array(p)


def test_pytree_roundtrip(tmp_path):
    tree = {"a": np.arange(10.0), "b": [np.ones((3, 4)),
                                        {"c": np.int32(7)}], "empty": ()}
    d = str(tmp_path / "snap")
    store.save_pytree(d, tree, {"step": 3})
    got, extra = store.load_pytree(d)
    assert extra["step"] == 3
    np.testing.assert_array_equal(got["a"], tree["a"])
    np.testing.assert_array_equal(got["b"][0], tree["b"][0])
    assert got["b"][1]["c"] == 7
    assert got["empty"] == ()


def test_uncommitted_dense_snapshot_ignored(tmp_path):
    d = str(tmp_path / "snap")
    store.save_pytree(d, {"x": np.ones(4)})
    os.remove(os.path.join(d, "COMMIT"))
    with pytest.raises(store.CorruptError):
        store.load_pytree(d)


def test_relaxed_gap_semantics(tmp_path):
    """dense_interval=3: the dense tier naturally trails the embedding tier
    by up to 2 steps (paper Fig. 9 relaxation); recovery reports the gap."""
    tmp = str(tmp_path / "ck")
    b, tc, cc, data = setup_run(tmp, dense_interval=3)
    init_fn, _, _, _ = train_loop.make_step_fns(b.model, tc)
    st0 = init_fn(jax.random.PRNGKey(0))
    mgr = CheckpointManager(b.model, cc, embed_init=st0["embed"])
    train_loop.train(b.model, tc, data, 5, relaxed=True, state=st0,
                     ckpt_manager=mgr)
    mgr.flush()
    # steps 0..4 ran; snapshots at 0 and 3 (GC keeps 3); mirror at 4
    rec = recovery.recover(tmp)
    assert rec.mirror_step == 4
    assert rec.dense_step == 3
    assert rec.gap == 1
    # resume still possible: embeddings exact at 4, dense stale by 1
    fresh = init_fn(jax.random.PRNGKey(0))
    st, resume = recovery.resume_train_state(rec, fresh)
    assert resume == 5


def test_undo_log_gc(tmp_path):
    tmp = str(tmp_path / "ck")
    cc = CheckpointConfig(directory=tmp, dense_interval=0, max_undo_logs=3)
    b = get_arch("tinyllama-1.1b", smoke=True)
    tc = TrainConfig(checkpoint=cc)
    init_fn, _, _, _ = train_loop.make_step_fns(b.model, tc)
    st0 = init_fn(jax.random.PRNGKey(0))
    mgr = CheckpointManager(b.model, cc, embed_init=st0["embed"])
    data = make_batches(b.model, 2, 8, seed=0)
    train_loop.train(b.model, tc, data, 8, relaxed=True, state=st0,
                     ckpt_manager=mgr)
    mgr.flush()
    steps = undo_log.committed_steps(tmp)
    assert len(steps) <= 4 and max(steps) == 7


def test_writer_deadline_skips_tier_m(tmp_path):
    tmp = str(tmp_path / "ck")
    cc = CheckpointConfig(directory=tmp, dense_interval=1,
                          writer_deadline_s=1e-9)
    b = get_arch("tinyllama-1.1b", smoke=True)
    tc = TrainConfig(checkpoint=cc)
    init_fn, _, _, _ = train_loop.make_step_fns(b.model, tc)
    st0 = init_fn(jax.random.PRNGKey(0))
    mgr = CheckpointManager(b.model, cc, embed_init=st0["embed"])
    data = make_batches(b.model, 2, 8, seed=0)
    train_loop.train(b.model, tc, data, 3, relaxed=True, state=st0,
                     ckpt_manager=mgr)
    mgr.flush()
    # relaxed semantics: tier-M never blocks; with an impossible deadline all
    # snapshots are skipped but tier-E stays consistent
    assert mgr.stats["tier_m_skipped"] >= 1
    rec = recovery.recover(tmp)
    assert rec.mirror_step == 2
