"""Failure-tolerance tests over the emulated memory pool: undo-ring
semantics, fault-injected crashes (between COMMIT and apply), torn mirror
writes, resume exactness, relaxed dense/embedding gap, GC, writer deadline.

Backend-parametrized tests honor REPRO_POOL_BACKENDS (default "dram,pmem");
CI's pool-backends job adds "remote", which runs the same drills through an
in-process pool-server (the memory node survives the simulated trainer
death; POOL.json reconnects recovery to it)."""
import os

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.configs.base import CheckpointConfig, TrainConfig
from repro.core.checkpoint import recovery, store
from repro.core.checkpoint.manager import CheckpointManager
from repro.data.synthetic import make_batches
from repro.pool import DramPool, FaultSchedule, InjectedCrash
from repro.training import train_loop

BACKENDS = [b.strip() for b in os.environ.get(
    "REPRO_POOL_BACKENDS", "dram,pmem").split(",") if b.strip()]
# pool-side compression mode under test (CI runs the suite with both
# "none" and "zlib"; recovery must be bit-identical either way)
COMPRESS = os.environ.get("REPRO_POOL_COMPRESS", "zlib")

_SERVERS = []    # in-process memory nodes; daemon threads, die with pytest


def setup_run(tmp, arch="tinyllama-1.1b", dense_interval=1, backend="pmem",
              compress=COMPRESS):
    addr, shards = "", ""
    if backend == "remote":
        from repro.pool import PoolServer
        srv = PoolServer(DramPool(1 << 20), f"unix:{tmp}.sock").start()
        _SERVERS.append(srv)
        addr = srv.addr
    elif backend == "sharded":
        from repro.pool import PoolServer
        srvs = [PoolServer(DramPool(1 << 20),
                           f"unix:{tmp}.s{i}.sock").start()
                for i in range(2)]
        _SERVERS.extend(srvs)
        shards = ",".join(s.addr for s in srvs)
    cc = CheckpointConfig(directory=tmp, dense_interval=dense_interval,
                          pool_backend=backend, pool_addr=addr,
                          pool_shards=shards, pool_compress=compress)
    tc = TrainConfig(embed_learning_rate=0.05, checkpoint=cc)
    b = get_arch(arch, smoke=True)
    data = make_batches(b.model, 4, 16, seed=3)
    return b, tc, cc, data


def run_with_manager(b, tc, cc, data, steps, faults=None):
    init_fn, _, _, _ = train_loop.make_step_fns(b.model, tc)
    st0 = init_fn(jax.random.PRNGKey(tc.seed))
    mgr = CheckpointManager(b.model, cc, embed_init=st0["embed"],
                            faults=faults)
    train_loop.train(b.model, tc, data, steps, relaxed=True, state=st0,
                     ckpt_manager=mgr)
    mgr.flush()
    return mgr


def test_resume_exact(tmp_path):
    tmp = str(tmp_path / "ck")
    b, tc, cc, data = setup_run(tmp)
    _, full = train_loop.train(b.model, tc, data, 8, relaxed=True)

    run_with_manager(b, tc, cc, data, 5).pool.close()

    rec = recovery.recover(tmp)
    assert rec.mirror_step == 4 and rec.dense_step == 4 and rec.gap == 0
    init_fn, _, _, _ = train_loop.make_step_fns(b.model, tc)
    fresh = init_fn(jax.random.PRNGKey(tc.seed))
    st, resume = recovery.resume_train_state(rec, fresh)
    _, tail = train_loop.train(b.model, tc, data, 3, relaxed=True, state=st,
                               start_step=resume)
    np.testing.assert_allclose(np.asarray(tail), np.asarray(full[5:]),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("backend", BACKENDS)
def test_crash_between_commit_and_apply(tmp_path, backend):
    """The paper's key failure scenario: power loss after the undo log's
    COMMIT flag persisted but before the mirror apply. Recovery must roll
    back to a bit-identical consistent state, and resuming must reproduce
    the uninterrupted run (idempotent re-apply)."""
    tmp = str(tmp_path / "ck")
    b, tc, cc, data = setup_run(tmp, backend=backend)
    _, full = train_loop.train(b.model, tc, data, 6, relaxed=True)

    # reference mirror: a clean run stopped after steps 0..2
    ref_tmp = str(tmp_path / "ref")
    _, _, ccr, _ = setup_run(ref_tmp, backend=backend)
    mref = run_with_manager(b, tc, ccr, data, 3)
    ref_rows = np.array(mref.mirror_rows)

    # faulted run: crash exactly between COMMIT and apply of step 3
    faults = FaultSchedule.crash_at("tier_e.between-commit-and-apply",
                                    occurrence=4)
    init_fn, _, _, _ = train_loop.make_step_fns(b.model, tc)
    st0 = init_fn(jax.random.PRNGKey(tc.seed))
    mgr = CheckpointManager(b.model, cc, embed_init=st0["embed"],
                            faults=faults)
    with pytest.raises(InjectedCrash):
        train_loop.train(b.model, tc, data, 6, relaxed=True, state=st0,
                         ckpt_manager=mgr)

    if backend == "dram":
        mgr.pool.crash()                   # power loss: cache dropped
        rec = recovery.recover(tmp, pool=mgr.pool)
    elif backend in ("remote", "sharded"):
        mgr.pool.crash()                   # memory-node power-cycle(s)...
        mgr.pool.close()                   # ...plus trainer death
        rec = recovery.recover(tmp)        # reconnect to the living node(s)
    else:
        mgr.pool.close()                   # process death: reopen from disk
        rec = recovery.recover(tmp)
    assert rec.mirror_step == 2
    np.testing.assert_array_equal(rec.embed_rows, ref_rows)  # bit-identical

    # idempotent re-apply: resume reproduces the uninterrupted run exactly
    fresh = init_fn(jax.random.PRNGKey(tc.seed))
    st, resume = recovery.resume_train_state(rec, fresh)
    assert resume == 3
    _, tail = train_loop.train(b.model, tc, data, 3, relaxed=True, state=st,
                               start_step=resume)
    np.testing.assert_allclose(np.asarray(tail), np.asarray(full[3:]),
                               rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("backend", BACKENDS)
def test_torn_mirror_apply_rolls_back(tmp_path, backend):
    """A torn persist mid-apply leaves garbage in some mirror rows; the
    COMMITted undo entry must restore them bit-exactly."""
    tmp = str(tmp_path / "ck")
    b, tc, cc, data = setup_run(tmp, backend=backend)

    ref_tmp = str(tmp_path / "ref")
    _, _, ccr, _ = setup_run(ref_tmp, backend=backend)
    mref = run_with_manager(b, tc, ccr, data, 2)
    ref_rows = np.array(mref.mirror_rows)

    faults = FaultSchedule.torn_at("mirror-apply", occurrence=3)
    init_fn, _, _, _ = train_loop.make_step_fns(b.model, tc)
    st0 = init_fn(jax.random.PRNGKey(tc.seed))
    mgr = CheckpointManager(b.model, cc, embed_init=st0["embed"],
                            faults=faults)
    with pytest.raises(InjectedCrash):
        train_loop.train(b.model, tc, data, 6, relaxed=True, state=st0,
                         ckpt_manager=mgr)
    mgr.pool.crash()
    rec = recovery.recover(tmp, pool=mgr.pool)
    assert rec.rolled_back
    assert rec.mirror_step == 1
    np.testing.assert_array_equal(rec.embed_rows, ref_rows)


def test_recovery_bit_identical_across_compression_modes(tmp_path):
    """Acceptance: the same crash drill recovers the same bytes whether
    pool-side compression is on or off — compression is transparent to the
    durability contract."""
    rows, dense_steps = {}, {}
    init_fn = None
    for comp in ("none", "zlib"):
        tmp = str(tmp_path / f"ck-{comp}")
        b, tc, cc, data = setup_run(tmp, backend="pmem", compress=comp)
        faults = FaultSchedule.crash_at("tier_e.between-commit-and-apply",
                                        occurrence=4)
        if init_fn is None:
            init_fn, _, _, _ = train_loop.make_step_fns(b.model, tc)
        st0 = init_fn(jax.random.PRNGKey(tc.seed))
        mgr = CheckpointManager(b.model, cc, embed_init=st0["embed"],
                                faults=faults)
        with pytest.raises(InjectedCrash):
            train_loop.train(b.model, tc, data, 6, relaxed=True, state=st0,
                             ckpt_manager=mgr)
        if comp == "zlib":       # the compressed cell really compressed
            assert 0 < mgr.stats["undo_stored_bytes"] \
                <= mgr.stats["undo_raw_bytes"]
        mgr.pool.close()
        rec = recovery.recover(tmp)
        rows[comp] = np.array(rec.embed_rows)
        dense_steps[comp] = rec.dense_step
        assert rec.mirror_step == 2
    np.testing.assert_array_equal(rows["none"], rows["zlib"])
    assert dense_steps["none"] == dense_steps["zlib"]


def test_crc_detects_corruption(tmp_path):
    p = str(tmp_path / "a.bin")
    store.write_array(p, np.arange(100000, dtype=np.float32))
    with open(p, "r+b") as f:
        f.seek(4096)
        f.write(b"\x13\x37")
    with pytest.raises(store.CorruptError):
        store.read_array(p)


def test_pytree_roundtrip(tmp_path):
    tree = {"a": np.arange(10.0), "b": [np.ones((3, 4)),
                                        {"c": np.int32(7)}], "empty": ()}
    d = str(tmp_path / "snap")
    store.save_pytree(d, tree, {"step": 3})
    got, extra = store.load_pytree(d)
    assert extra["step"] == 3
    np.testing.assert_array_equal(got["a"], tree["a"])
    np.testing.assert_array_equal(got["b"][0], tree["b"][0])
    assert got["b"][1]["c"] == 7
    assert got["empty"] == ()


def test_tree_blob_roundtrip_and_crc():
    # the empty tuple flattens to a 0-byte leaf — its (empty) chunk record
    # must not misalign the records that follow it in the blob
    tree = {"a": np.arange(6.0).reshape(2, 3), "b": {"c": np.int64(5)},
            "empty": (), "z": np.zeros((0,), np.float32)}
    blob = store.serialize_tree(tree, {"step": 9})
    got, extra = store.deserialize_tree(blob)
    assert extra["step"] == 9
    np.testing.assert_array_equal(got["a"], tree["a"])
    assert got["b"]["c"] == 5
    assert got["empty"] == () and got["z"].size == 0
    bad = bytearray(blob)
    bad[-3] ^= 0xFF
    with pytest.raises(store.CorruptError):
        store.deserialize_tree(bytes(bad))


def test_uncommitted_dense_snapshot_ignored(tmp_path):
    d = str(tmp_path / "snap")
    store.save_pytree(d, {"x": np.ones(4)})
    os.remove(os.path.join(d, "COMMIT"))
    with pytest.raises(store.CorruptError):
        store.load_pytree(d)


def test_corrupt_dense_blob_falls_back(tmp_path):
    """A corrupted in-pool dense snapshot degrades to dense=None (the mirror
    tier stays authoritative) instead of failing recovery."""
    tmp = str(tmp_path / "ck")
    b, tc, cc, data = setup_run(tmp)
    mgr = run_with_manager(b, tc, cc, data, 3)
    region = mgr.dense_dom.get(f"slot{mgr.manifest.read()['dense_slot']}")
    buf = mgr.pool.view(region.off, 64)
    buf[20:30] ^= 0xFF                       # corrupt the durable blob
    mgr.pool.mark_dirty(region.off, 64)
    mgr.pool.persist(point="corruption")
    rec = recovery.recover(tmp, pool=mgr.pool)
    assert rec.dense is None and rec.dense_step == -1
    assert rec.mirror_step == 2              # embedding tier unaffected


def test_relaxed_gap_semantics(tmp_path):
    """dense_interval=3: the dense tier naturally trails the embedding tier
    by up to 2 steps (paper Fig. 9 relaxation); recovery reports the gap."""
    tmp = str(tmp_path / "ck")
    b, tc, cc, data = setup_run(tmp, dense_interval=3)
    run_with_manager(b, tc, cc, data, 5).pool.close()
    # steps 0..4 ran; snapshots at 0 and 3 (slot flip keeps 3); mirror at 4
    rec = recovery.recover(tmp)
    assert rec.mirror_step == 4
    assert rec.dense_step == 3
    assert rec.gap == 1
    init_fn, _, _, _ = train_loop.make_step_fns(b.model, tc)
    fresh = init_fn(jax.random.PRNGKey(0))
    st, resume = recovery.resume_train_state(rec, fresh)
    assert resume == 5


def test_undo_log_gc(tmp_path):
    tmp = str(tmp_path / "ck")
    cc = CheckpointConfig(directory=tmp, dense_interval=0, max_undo_logs=3)
    b = get_arch("tinyllama-1.1b", smoke=True)
    tc = TrainConfig(checkpoint=cc)
    data = make_batches(b.model, 2, 8, seed=0)
    mgr = run_with_manager(b, tc, cc, data, 8)
    steps = mgr.ring.committed_steps()
    assert len(steps) <= 4 and max(steps) == 7


def test_writer_deadline_skips_tier_m(tmp_path):
    tmp = str(tmp_path / "ck")
    cc = CheckpointConfig(directory=tmp, dense_interval=1,
                          writer_deadline_s=1e-9)
    b = get_arch("tinyllama-1.1b", smoke=True)
    tc = TrainConfig(checkpoint=cc)
    data = make_batches(b.model, 2, 8, seed=0)
    mgr = run_with_manager(b, tc, cc, data, 3)
    # relaxed semantics: tier-M never blocks; with an impossible deadline all
    # snapshots are skipped but tier-E stays consistent
    assert mgr.stats["tier_m_skipped"] >= 1
    rec = recovery.recover(tmp, pool=mgr.pool)
    assert rec.mirror_step == 2
