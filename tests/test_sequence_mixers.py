"""Chunked sequence mixers vs sequential oracles: wkv6 (rwkv) and SSD
(mamba) — the chunked matmul forms must match step-by-step recurrences."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the optional dev dep
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.models import mamba as mamba_mod
from repro.models import rwkv6 as rwkv_mod


def _wkv_case(rng, B, S, H, K):
    r, k, v = (jnp.asarray(rng.standard_normal((B, S, H, K))
                           .astype(np.float32) * 0.5) for _ in range(3))
    logw = jnp.asarray(-np.exp(rng.standard_normal((B, S, H, K)) * 0.5 - 1)
                       .astype(np.float32))
    logw = jnp.clip(logw, rwkv_mod.LOG_W_MIN, -1e-4)
    u = jnp.asarray(rng.standard_normal((H, K)).astype(np.float32) * 0.3)
    s0 = jnp.asarray(rng.standard_normal((B, H, K, K)).astype(np.float32) * 0.1)
    return r, k, v, logw, u, s0


@pytest.mark.parametrize("B,S,H,K,chunk", [
    (2, 64, 2, 64, 16), (1, 48, 1, 64, 16), (2, 33, 2, 64, 16)])
def test_wkv6_chunked_vs_sequential(rng, B, S, H, K, chunk):
    r, k, v, logw, u, s0 = _wkv_case(rng, B, S, H, K)
    y_c, s_c = rwkv_mod.wkv6_chunked(r, k, v, logw, u, s0, chunk=chunk)
    y_r, s_r = ref.wkv6_ref(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_r),
                               rtol=2e-4, atol=2e-4)


@settings(deadline=None, max_examples=8)
@given(seed=st.integers(0, 500), s=st.integers(4, 70))
def test_property_wkv6(seed, s):
    rng = np.random.default_rng(seed)
    r, k, v, logw, u, s0 = _wkv_case(rng, 1, s, 1, 64)
    y_c, s_c = rwkv_mod.wkv6_chunked(r, k, v, logw, u, s0)
    y_r, s_r = ref.wkv6_ref(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r),
                               rtol=3e-4, atol=3e-4)


@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (2, 64, 2, 64, 16, 16), (1, 40, 1, 64, 8, 16)])
def test_mamba_ssd_chunked_vs_sequential(rng, B, S, H, P, N, chunk):
    xh = jnp.asarray(rng.standard_normal((B, S, H, P)).astype(np.float32))
    dt = jnp.asarray(np.abs(rng.standard_normal((B, S, H)))
                     .astype(np.float32) * 0.1)
    a = -jnp.asarray(np.abs(rng.standard_normal((H,))).astype(np.float32) + .1)
    B_ = jnp.asarray(rng.standard_normal((B, S, N)).astype(np.float32))
    C_ = jnp.asarray(rng.standard_normal((B, S, N)).astype(np.float32))
    y_c, h_c = mamba_mod._ssd_chunked(xh, dt, a, B_, C_, chunk)
    y_r, h_r = ref.mamba_ssd_ref(xh, dt, a, B_, C_)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_r),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(h_c), np.asarray(h_r),
                               rtol=2e-4, atol=2e-4)


def test_rwkv_decode_matches_prefill(rng):
    """Recurrent state handoff: prefill(S) then decode == prefill(S+1)."""
    from repro.configs import get_arch
    from repro.models.registry import get_api
    b = get_arch("rwkv6-3b", smoke=True)
    cfg = b.model
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 9)).astype(np.int32))
    caches = api.init_cache(cfg, 2, 16)
    logits_a, caches = api.prefill(params, cfg, toks[:, :8], caches)
    logits_b, _ = api.decode_step(params, cfg, toks[:, 8:9], 8, caches)
    caches2 = api.init_cache(cfg, 2, 16)
    logits_full, _ = api.prefill(params, cfg, toks, caches2)
    np.testing.assert_allclose(np.asarray(logits_b), np.asarray(logits_full),
                               rtol=2e-4, atol=2e-4)


def test_transformer_decode_matches_prefill(rng):
    from repro.configs import get_arch
    from repro.models.registry import get_api
    b = get_arch("tinyllama-1.1b", smoke=True)
    cfg = b.model
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 9)).astype(np.int32))
    caches = api.init_cache(cfg, 2, 16)
    logits_a, caches = api.prefill(params, cfg, toks[:, :8], caches)
    logits_b, _ = api.decode_step(params, cfg, toks[:, 8:9], 8, caches)
    caches2 = api.init_cache(cfg, 2, 16)
    logits_full, _ = api.prefill(params, cfg, toks, caches2)
    np.testing.assert_allclose(np.asarray(logits_b), np.asarray(logits_full),
                               rtol=2e-3, atol=2e-3)


def test_jamba_decode_matches_prefill(rng):
    from repro.configs import get_arch
    from repro.models.registry import get_api
    b = get_arch("jamba-v0.1-52b", smoke=True)
    cfg = b.model
    api = get_api(cfg)
    params = api.init(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (2, 9)).astype(np.int32))
    caches = api.init_cache(cfg, 2, 16)
    _, caches = api.prefill(params, cfg, toks[:, :8], caches)
    logits_b, _ = api.decode_step(params, cfg, toks[:, 8:9], 8, caches)
    caches2 = api.init_cache(cfg, 2, 16)
    logits_full, _ = api.prefill(params, cfg, toks, caches2)
    np.testing.assert_allclose(np.asarray(logits_b), np.asarray(logits_full),
                               rtol=2e-3, atol=2e-3)
