"""Crash-consistency checker + repo-specific invariant lints.

Three layers:

  * known-bad persistence sequences each raise the exact typed
    ``OrderingViolation`` (rules U/C/P/F), and the wrapper composes over
    the dram, pmem and remote backends;
  * the static linter (``repro.analysis.lint``) is clean on the real src
    tree and loud — with file:line diagnostics — on the seeded bad fixture
    in ``tests/fixtures/lint_bad.py``;
  * arming drills for every named barrier the R1b dead-point rule flagged:
    the migration/replica persist points, the undo-ring gc/grow-scrub
    points, and the manager manifest points + the recovery rollback. Each
    drill fires the real point through the real code path and proves the
    retry/recovery stays consistent. The sharded drills run over
    ``CheckedPool``-wrapped shard devices, so they double as the negative
    proof that the epoch-publish and open-time-sweep paths are
    persist-clean under the checker.
"""
import json
import os

import numpy as np
import pytest

from repro.analysis import lint
from repro.analysis.checker import (CheckedPool, CommitBeforePayloadError,
                                    DoubleFreeError, RegionOverlapError,
                                    ShadowTracker, UnpersistedReadError,
                                    UseAfterFreeError, WriteAfterPublishError)
from repro.core.checkpoint.undo_log import UndoRing
from repro.pool import (DramPool, FaultSchedule, InjectedCrash, PmemPool,
                        PoolAllocator, PoolServer, ShardedPool)
from repro.pool.allocator import JsonRegion
from repro.pool import undo_codec as uc
from repro.pool.device import make_pool

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _dram_checked():
    return make_pool("dram", capacity=1 << 20, check=True)


def _domain_bytes(pool, domain):
    out = {}
    for name, r in PoolAllocator(pool).domain(domain).regions().items():
        out[name] = bytes(pool.read(r.off, r.nbytes, tag="oracle"))
    return out


# ---------------------------------------------------------------------------
# checker: known-bad sequences raise the right typed violation
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["dram", "pmem"])
def test_clean_two_barrier_flow_passes(tmp_path, backend):
    """The paper's payload-then-COMMIT protocol is clean under the checker,
    across a power cycle."""
    dev = make_pool(backend, path=str(tmp_path / "p.img"),
                    capacity=1 << 20, check=True)
    assert isinstance(dev, CheckedPool)
    r = PoolAllocator(dev).domain("d").alloc("ring", shape=(4096,),
                                             dtype="uint8")
    buf, _, _ = uc.pack_slot(1, np.arange(4, dtype=np.int64),
                             np.ones((4, 8), np.float32), None,
                             mode="none", slot_bytes=1024)
    uc.write_slot(dev, r.off, buf)
    dev.crash()
    hdr = uc.parse_header(bytes(dev.read(r.off, uc.HDR.size)), 1024)
    assert hdr is not None and hdr[0] == 1
    dev.close()


def test_commit_before_payload_raises():
    """COMMIT barrier with the payload persist skipped = rule C."""
    dev = _dram_checked()
    r = PoolAllocator(dev).domain("d").alloc("ring", shape=(4096,),
                                             dtype="uint8")
    buf, _, _ = uc.pack_slot(1, np.arange(4, dtype=np.int64),
                             np.ones((4, 8), np.float32), None,
                             mode="none", slot_bytes=1024)
    dev.write(r.off, buf)                       # payload never persisted
    dev.write(r.off + uc.COMMIT_OFF, uc.COMMIT_SET)
    with pytest.raises(CommitBeforePayloadError):
        dev.persist(r.off + uc.COMMIT_OFF, 4, point="undo-commit")


def test_unpersisted_read_after_crash_raises():
    dev = _dram_checked()
    r = PoolAllocator(dev).domain("d").alloc("x", shape=(64,), dtype="uint8")
    dev.write(r.off, b"\x7f" * 64)              # no persist
    dev.crash()
    with pytest.raises(UnpersistedReadError):
        dev.read(r.off, 64)


def test_write_after_publish_raises_until_sibling_publish():
    dev = _dram_checked()
    dom = PoolAllocator(dev).domain("d")
    dom.alloc("a", shape=(128,), dtype="uint8")
    assert len(dev.tracker.sealed) == 1         # superblock slot sealed
    lo, hi = dev.tracker.sealed[0]
    with pytest.raises(WriteAfterPublishError):
        dev.write(lo, b"\x00")
    # the sibling publish supersedes the seal: the old slot is spare again
    dom.alloc("b", shape=(128,), dtype="uint8")
    assert len(dev.tracker.sealed) == 1
    assert dev.tracker.sealed[0] != (lo, hi)


def test_device_use_after_free_through_directory():
    """The wrapper tracks region lifecycle by diffing the superblock the
    allocator publishes — a read through a stale handle is caught."""
    dev = _dram_checked()
    dom = PoolAllocator(dev).domain("d")
    r = dom.alloc("x", shape=(256,), dtype="uint8")
    dev.write(r.off, b"z" * 256)
    dev.persist(r.off, 256)
    dom.free_region("x")
    with pytest.raises(UseAfterFreeError):
        dev.read(r.off, 16)


def test_tracker_double_free_and_overlap():
    t = ShadowTracker("t")
    t.note_alloc(("d", "r"), 0x1000, 64)
    t.note_free(("d", "r"), 0x1000, 64)
    with pytest.raises(UseAfterFreeError):
        t.note_read(0x1000, 8)
    with pytest.raises(UseAfterFreeError):
        t.note_write(0x1010, 8)
    with pytest.raises(DoubleFreeError):
        t.note_free(("d", "r"), 0x1000, 64)
    t2 = ShadowTracker("t2")
    t2.note_alloc("a", 0, 100)
    with pytest.raises(RegionOverlapError):
        t2.note_alloc("b", 50, 150)


def test_checker_off_by_default(monkeypatch):
    monkeypatch.delenv("REPRO_POOL_CHECK", raising=False)
    dev = make_pool("dram", capacity=1 << 16)
    assert isinstance(dev, DramPool)            # zero default-path overhead
    dev = make_pool("dram", capacity=1 << 16, check=True)
    assert isinstance(dev, CheckedPool)
    monkeypatch.setenv("REPRO_POOL_CHECK", "1")
    dev = make_pool("dram", capacity=1 << 16)
    assert isinstance(dev, CheckedPool)
    assert isinstance(dev.inner, DramPool)
    dev = make_pool("dram", capacity=1 << 16, check=False)
    assert isinstance(dev, DramPool)            # explicit opt-out wins


def test_checked_remote_composes(tmp_path):
    """The wrapper over a RemotePool: clean flow across a node power-cycle,
    and rule U on a write the node never flushed."""
    srv = PoolServer(DramPool(1 << 20), f"unix:{tmp_path}/r.sock").start()
    try:
        dev = make_pool("remote", addr=srv.addr, check=True)
        assert isinstance(dev, CheckedPool)
        r = PoolAllocator(dev).domain("d").alloc("x", shape=(64,),
                                                 dtype="uint8")
        dev.write(r.off, b"a" * 64)
        dev.persist(r.off, 64)
        dev.crash()                             # node power-cycle
        assert bytes(dev.read(r.off, 64)) == b"a" * 64
        dev.write(r.off, b"b" * 64)             # volatile on the node
        dev.crash()
        with pytest.raises(UnpersistedReadError):
            dev.read(r.off, 64)
        dev.close()
    finally:
        srv.shutdown(close_device=True)


def test_refresh_capacity_sees_foreign_growth(tmp_path):
    """Regression for the R2a lint finding: the ``capacity`` op had a server
    arm but no client stub, so a client could never refresh its cached
    gauge after another connection grew the shared device."""
    from repro.pool.remote import RemotePool
    srv = PoolServer(DramPool(1 << 20), f"unix:{tmp_path}/c.sock").start()
    try:
        a = RemotePool(srv.addr)
        b = RemotePool(srv.addr)
        cap0 = a.capacity
        b.ensure(cap0 + (1 << 20))
        assert a.capacity == cap0               # cached gauge is stale
        got = a.refresh_capacity()
        assert got >= cap0 + (1 << 20)
        assert a.capacity == got == srv.device.capacity
        a.close()
        b.close()
    finally:
        srv.shutdown(close_device=True)


# ---------------------------------------------------------------------------
# the linter: clean on src, loud on the seeded fixture
# ---------------------------------------------------------------------------


def test_lint_clean_on_src_tree():
    findings = lint.run([os.path.join(REPO, "src", "repro")])
    assert findings == [], "\n".join(str(f) for f in findings)


def test_lint_flags_seeded_fixture():
    fixture = os.path.join(REPO, "tests", "fixtures", "lint_bad.py")
    findings = lint.run([fixture])
    rules = {f.rule for f in findings}
    assert {"R1a-typo-arm", "R1c-unregistered-point",
            "R2d-unknown-nmp-kind", "R3-lock-cycle",
            "R4-socket-under-lock"} <= rules, rules
    for f in findings:                          # file:line diagnostics
        assert f.path.endswith("lint_bad.py") and f.line > 0
        assert str(f).startswith(f"{f.path}:{f.line}: [{f.rule}]")


def test_lint_v3_codec_rule_clean_and_loud(monkeypatch):
    """R5: clean on the real registry; a binary kind declared without a
    codec (or a codec naming an unknown op) is flagged."""
    from repro.pool import protocol
    findings = []
    lint._rule_v3(findings)
    assert findings == [], findings
    monkeypatch.setattr(protocol, "_V3_NMP_KINDS",
                        protocol._V3_NMP_KINDS + ("ghost_kind",))
    findings = []
    lint._rule_v3(findings)
    assert any(f.rule == "R5a-missing-v3-codec" and "ghost_kind" in f.msg
               for f in findings), findings


def test_lint_copy_rule_flags_unannotated_bytes(tmp_path):
    """R6: a bytes()/tobytes()/join copy in a data-path file is a finding
    unless the line (or the one above) carries '# wire-copy:'."""
    pdir = tmp_path / "pool"
    pdir.mkdir()
    bad = pdir / "remote.py"
    bad.write_text(
        "def leak(mv, arr, segs):\n"
        "    a = bytes(mv)\n"
        "    b = arr.tobytes()\n"
        "    c = b\"\".join(segs)\n"
        "    # wire-copy: sanctioned staging copy\n"
        "    d = bytes(mv)\n"
        "    e = arr.tobytes()  # wire-copy: sanctioned inline\n"
        "    return a, b, c, d, e\n")
    findings = []
    lint._rule_copies([str(bad)], findings)
    assert [f.line for f in findings] == [2, 3, 4], findings
    assert all(f.rule == "R6-copy-on-data-path" for f in findings)
    # non-data-path files are out of scope
    other = tmp_path / "elsewhere.py"
    other.write_text("x = bytes(b'ab')\n")
    findings = []
    lint._rule_copies([str(other)], findings)
    assert findings == []


def test_lint_main_exit_codes(capsys):
    assert lint.main([os.path.join(REPO, "src", "repro")]) == 0
    fixture = os.path.join(REPO, "tests", "fixtures", "lint_bad.py")
    assert lint.main([fixture]) == 1
    assert "lint_bad.py:" in capsys.readouterr().out


# ---------------------------------------------------------------------------
# arming drills: migration / replication barrier points
# (sharded over CheckedPool-wrapped devices — the epoch-publish and sweep
# paths must also be persist-clean under the checker)
# ---------------------------------------------------------------------------


def _checked_sharded(nshards=2):
    return ShardedPool([CheckedPool(DramPool(1 << 20))
                        for _ in range(nshards)])


def _seed_mirror(pool, rng):
    a = PoolAllocator(pool)
    tab = rng.standard_normal((64, 8)).astype(np.float32)
    mirror = a.domain("embedding-mirror").alloc("rows", shape=tab.shape,
                                                dtype="float32")
    mirror.write_array(tab)
    mirror.persist(point="mirror-load")
    return tab


@pytest.mark.parametrize("point",
                         ["migrate-alloc", "migrate-import", "migrate-gc"])
def test_migration_barrier_points_fire(point):
    """Crash at each per-region migration barrier: the copy is interrupted,
    the sweep reclaims the stranded side, the surviving image is
    bit-identical."""
    rng = np.random.default_rng(7)
    pool = _checked_sharded()
    _seed_mirror(pool, rng)
    src = pool.placement.place("embedding-mirror")
    dst = 1 - src
    oracle = _domain_bytes(pool, "embedding-mirror")
    pool.faults = FaultSchedule.crash_at(point)
    with pytest.raises(InjectedCrash):
        pool.migrate_domain("embedding-mirror", dst)
    pool.faults = None
    pool.sweep_stale_domains()
    # gc fires after the flip; the copy barriers fire before it
    owner = dst if point == "migrate-gc" else src
    assert pool.placement.place("embedding-mirror") == owner
    assert _domain_bytes(pool, "embedding-mirror") == oracle
    pool.close()


def test_migrate_sweep_point_fires():
    """Strand a source copy (crash after the flip, before gc), then crash
    the sweep's own free barrier; the re-run sweep leaves one owner."""
    rng = np.random.default_rng(13)
    pool = _checked_sharded()
    _seed_mirror(pool, rng)
    src = pool.placement.place("embedding-mirror")
    dst = 1 - src
    oracle = _domain_bytes(pool, "embedding-mirror")
    pool.faults = FaultSchedule.crash_at("migrate.post-flip-pre-gc")
    with pytest.raises(InjectedCrash):
        pool.migrate_domain("embedding-mirror", dst)
    pool.faults = FaultSchedule.crash_at("migrate-sweep")
    with pytest.raises(InjectedCrash):
        pool.sweep_stale_domains()
    pool.faults = None
    pool.sweep_stale_domains()
    assert "embedding-mirror" not in pool.shard_domains(src)
    assert pool.placement.place("embedding-mirror") == dst
    assert _domain_bytes(pool, "embedding-mirror") == oracle
    pool.close()


@pytest.mark.parametrize("point", ["replica-alloc", "replica-import",
                                   "replica-watermark"])
def test_replica_barrier_points_fire(point):
    """Crash at each replica-refresh barrier, then retry clean: the replica
    converges to the primary's bytes and the watermark lands."""
    rng = np.random.default_rng(11)
    pool = _checked_sharded()
    tab = _seed_mirror(pool, rng)
    src = pool.placement.place("embedding-mirror")
    dst = 1 - src
    pool.faults = FaultSchedule.crash_at(point)
    with pytest.raises(InjectedCrash):
        pool.replicate_domain("embedding-mirror", dst, watermark=3)
    pool.faults = None
    info = pool.replicate_domain("embedding-mirror", dst, watermark=5)
    assert info["dst"] == dst and info["regions"] >= 1
    rep = pool.shards[dst].list_regions(info["replica"])
    assert "rows" in rep and "watermark" in rep
    got = pool.shards[dst].device.read(
        int(rep["rows"]["off"]), int(rep["rows"]["nbytes"]), tag="drill")
    np.testing.assert_array_equal(
        np.asarray(got).view(np.float32).reshape(tab.shape), tab)
    pool.close()


def _promoted_ctx(rng):
    """3 checked shards, mirror+ring live on their placed shard, replicas of
    both refreshed onto a second shard — the promotion drills' start."""
    pool = _checked_sharded(3)
    tab = _seed_mirror(pool, rng)
    a = PoolAllocator(pool)
    ring = UndoRing(a, max_logs=4, compress="zlib")
    idx = np.unique(rng.integers(0, 64, 12))
    new = rng.standard_normal((idx.size, 8)).astype(np.float32)
    ring.log_and_apply(0, a.domain("embedding-mirror").get("rows"), idx, new)
    src = pool.placement.place("embedding-mirror")
    dst = (src + 1) % 3
    pool.replicate_domain("embedding-mirror", dst, watermark=0)
    pool.replicate_domain("undo-log", dst, watermark=0)
    return pool, ring, src, dst


@pytest.mark.parametrize("point", ["promote.pre-copy", "promote-alloc",
                                   "promote.mid-copy", "promote-import",
                                   "promote.post-copy-pre-flip"])
def test_promotion_pre_flip_crash_leaves_placement_unmoved(point):
    """Crash anywhere before the promotion's epoch flip: the domain is
    still routed at the (lost) source — recovery would simply retry — and
    the re-run converges, carrying the whole alias group in one epoch."""
    rng = np.random.default_rng(23)
    pool, ring, src, dst = _promoted_ctx(rng)
    replica_rows = _domain_bytes(pool, "embedding-mirror@replica")["rows"]
    pool.faults = FaultSchedule.crash_at(point)
    with pytest.raises(InjectedCrash):
        pool.promote_replica("embedding-mirror")
    pool.faults = None
    assert pool.placement.place("embedding-mirror") == src
    assert pool.placement.place("undo-log") == src
    info = pool.promote_replica("embedding-mirror")
    assert set(info["promoted"]) == {"embedding-mirror", "undo-log"}
    assert pool.placement.place("embedding-mirror") == dst
    assert pool.placement.place("undo-log") == dst
    assert _domain_bytes(pool, "embedding-mirror")["rows"] == replica_rows
    pool.close()


def test_promotion_post_flip_crash_is_already_promoted():
    """Crash AFTER the flip ("promote.post-flip"): the epoch already
    committed, so the promoted copy is authoritative — rerunning recovery
    must not re-route or re-copy anything."""
    rng = np.random.default_rng(29)
    pool, ring, src, dst = _promoted_ctx(rng)
    pool.faults = FaultSchedule.crash_at("promote.post-flip")
    with pytest.raises(InjectedCrash):
        pool.promote_replica("embedding-mirror")
    pool.faults = None
    assert pool.placement.place("embedding-mirror") == dst
    assert pool.placement.place("undo-log") == dst
    oracle = _domain_bytes(pool, "embedding-mirror")
    # the lost source is never GC'd by promotion itself; if that shard ever
    # reappears (here it never died — in-process drill), the open-time
    # sweep reclaims its stale copies, and the promoted image is untouched
    assert sorted(pool.sweep_stale_domains()) == [
        ("embedding-mirror", src), ("undo-log", src)]
    assert _domain_bytes(pool, "embedding-mirror") == oracle
    pool.close()


def test_promotion_gc_point_reclaims_stranded_shape():
    """A crashed earlier promotion stranded a same-name region of an OLDER
    shape under the real domain name on the replica shard: the re-run frees
    it at the "promote-gc" barrier (drilled), then lands the fresh copy."""
    rng = np.random.default_rng(31)
    pool, ring, src, dst = _promoted_ctx(rng)
    pool.shards[dst].alloc_region("embedding-mirror", "rows", (8, 8),
                                  "float32", "promote-alloc")
    pool.faults = FaultSchedule.crash_at("promote-gc")
    with pytest.raises(InjectedCrash):
        pool.promote_replica("embedding-mirror")
    pool.faults = None
    info = pool.promote_replica("embedding-mirror")
    assert info["regions"] >= 2                 # rows + watermark (+ ring)
    got = _domain_bytes(pool, "embedding-mirror")
    assert got["rows"] == _domain_bytes(pool,
                                        "embedding-mirror@replica")["rows"]
    pool.close()


def test_replica_gc_point_fires_on_retired_source_region():
    """The source renames a region (ring regrowth); the refresh frees the
    stale replica name at the "replica-gc" barrier (drilled), and the clean
    retry leaves the replica directory an exact mirror of the source's."""
    rng = np.random.default_rng(37)
    pool = _checked_sharded(2)
    _seed_mirror(pool, rng)
    src = pool.placement.place("embedding-mirror")
    dst = 1 - src
    pool.replicate_domain("embedding-mirror", dst, watermark=0)
    a = PoolAllocator(pool)
    dom = a.domain("embedding-mirror")
    dom.free_region("rows")
    r2 = dom.alloc("rows2", shape=(32, 8), dtype="float32")
    r2.write_array(np.ones((32, 8), np.float32))
    r2.persist(point="mirror-load")
    pool.faults = FaultSchedule.crash_at("replica-gc")
    with pytest.raises(InjectedCrash):
        pool.replicate_domain("embedding-mirror", dst, watermark=1)
    pool.faults = None
    pool.replicate_domain("embedding-mirror", dst, watermark=1)
    rep = pool.shards[dst].list_regions("embedding-mirror@replica")
    assert set(rep) == {"rows2", "watermark"}
    pool.close()


def test_commit_ship_point_fires_and_slot_lands():
    """Crash at the "replica.commit-ship" window, then retry: the verbatim
    slot image lands inside the replica ring at the same slot offset, and
    the destination re-commits it under the same two-barrier protocol (all
    bytes equal except the COMMIT word, which the shipped image carries
    cleared and write_slot sets last)."""
    rng = np.random.default_rng(41)
    pool, ring, src, dst = _promoted_ctx(rng)
    name, slot_off, buf = ring.slot_image(0)
    pool.faults = FaultSchedule.crash_at("replica.commit-ship")
    with pytest.raises(InjectedCrash):
        pool.ship_slot("undo-log", name, slot_off, buf)
    pool.faults = None
    assert pool.ship_slot("undo-log", name, slot_off, buf) == len(buf)
    rep = pool.shards[dst].list_regions("undo-log@replica")
    got = bytes(pool.shards[dst].device.read(
        int(rep[name]["off"]) + slot_off, len(buf), tag="drill"))
    assert got[:uc.COMMIT_OFF] == buf[:uc.COMMIT_OFF]
    assert got[uc.HDR.size:] == buf[uc.HDR.size:]
    assert int.from_bytes(got[uc.COMMIT_OFF:uc.HDR.size], "little") != 0
    pool.close()


def test_manifest_witness_publish_is_ab_safe():
    """The quorum witnesses advance through the same A/B single-publish
    election as the primary manifest: a crash at the "manifest-witness"
    publish leaves a sealed image electable (old or new, never torn), and
    the retry converges."""
    pool = _checked_sharded(3)
    jr = JsonRegion.create(PoolAllocator(pool).domain("manifest@w1"),
                           "manifest")
    jr.write({"mirror_step": 1}, point="manifest-witness")
    pool.faults = FaultSchedule.crash_at("manifest-witness")
    with pytest.raises(InjectedCrash):
        jr.write({"mirror_step": 2}, point="manifest-witness")
    pool.faults = None
    assert (jr.read() or {}).get("mirror_step") in (1, 2)
    jr.write({"mirror_step": 2}, point="manifest-witness")
    assert (jr.read() or {}).get("mirror_step") == 2
    pool.close()


def test_epoch_publish_and_sweep_clean_under_checker(tmp_path):
    """Negative proof (no persist-coverage gap): a full clean migration —
    copy, epoch publish, source gc — plus the open-time sweep, with every
    shard device wrapped in CheckedPool. Any missing persist in the publish
    or sweep path would raise a typed violation here."""
    rng = np.random.default_rng(17)
    pool = _checked_sharded()
    sink_file = str(tmp_path / "placement.json")

    def sink(pm):
        with open(sink_file, "w") as f:
            json.dump(pm.to_json(), f)

    pool.epoch_sink = sink
    _seed_mirror(pool, rng)
    a = PoolAllocator(pool)
    ring = UndoRing(a, max_logs=4, compress="zlib")
    idx = np.unique(rng.integers(0, 64, 12))
    new = rng.standard_normal((idx.size, 8)).astype(np.float32)
    ring.log_and_apply(0, a.domain("embedding-mirror").get("rows"), idx, new)
    src = pool.placement.place("embedding-mirror")
    dst = 1 - src
    oracle = {d: _domain_bytes(pool, d)
              for d in ("embedding-mirror", "undo-log")}
    info = pool.migrate_domain("embedding-mirror", dst, compress="zlib")
    assert "embedding-mirror" in info["moved"]
    assert pool.sweep_stale_domains() == []     # clean flip GC'd the source
    for dom, regions in oracle.items():
        assert _domain_bytes(pool, dom) == regions
    # the trackers saw real traffic on both sides and no rule fired
    assert all(s.device.tracker.events["persist"] > 0 for s in pool.shards)
    pool.close()


# ---------------------------------------------------------------------------
# arming drills: undo-ring gc + grow-scrub
# ---------------------------------------------------------------------------


def test_undo_gc_point_fires():
    dev = _dram_checked()
    faults = FaultSchedule.drop_at("undo-gc")
    dev.faults = faults
    ring = UndoRing(PoolAllocator(dev), max_logs=2, compress="none")
    rng = np.random.default_rng(3)
    for step in range(5):
        ring.append(step, np.arange(4, dtype=np.int64),
                    rng.standard_normal((4, 8)).astype(np.float32))
    ring.gc(3)
    assert faults.counts.get("undo-gc", 0) >= 1
    assert set(ring.committed_steps()) == {3, 4}


def test_undo_grow_scrub_point_fires():
    """Crash a ring grow right after the new generation's alloc published
    (ring1 exists, meta still points at ring0); the re-attached writer's
    next grow must scrub the half-built generation before reuse."""
    dev = _dram_checked()
    ring = UndoRing(PoolAllocator(dev), max_logs=2, compress="none")
    rng = np.random.default_rng(5)
    small_idx = np.arange(2, dtype=np.int64)
    small = rng.standard_normal((2, 4)).astype(np.float32)
    ring.append(0, small_idx, small)
    dev.faults = FaultSchedule.crash_at("undo-grow-alloc")
    big_idx = np.arange(64, dtype=np.int64)
    big = rng.standard_normal((64, 32)).astype(np.float32)
    with pytest.raises(InjectedCrash):
        ring.append(1, big_idx, big)
    scrub = FaultSchedule.drop_at("undo-grow-scrub", occurrence=10 ** 9)
    dev.faults = scrub
    ring2 = UndoRing(PoolAllocator(dev), max_logs=2, compress="none")
    ring2.append(1, big_idx, big)               # grow reuses + scrubs ring1
    assert scrub.counts.get("undo-grow-scrub", 0) >= 1
    got_idx, got_rows, _ = ring2.read(0)        # carried over intact
    np.testing.assert_array_equal(got_idx, small_idx)
    np.testing.assert_array_equal(got_rows, small)
    g1_idx, g1_rows, _ = ring2.read(1)
    np.testing.assert_array_equal(g1_idx, big_idx)
    np.testing.assert_array_equal(g1_rows, big)


# ---------------------------------------------------------------------------
# arming drills: manager manifest points + recovery rollback
# ---------------------------------------------------------------------------


def _smoke_setup(tmp, dense_interval=1):
    from repro.configs import get_arch
    from repro.configs.base import CheckpointConfig, TrainConfig
    from repro.data.synthetic import make_batches
    cc = CheckpointConfig(directory=tmp, dense_interval=dense_interval,
                          pool_backend="pmem", pool_compress="zlib")
    b = get_arch("tinyllama-1.1b", smoke=True)
    tc = TrainConfig(embed_learning_rate=0.05, checkpoint=cc)
    data = make_batches(b.model, 4, 16, seed=3)
    return b, tc, cc, data


def test_manager_manifest_points_fire(tmp_path):
    """Silent drop faults on the manifest barriers and the apply/manifest
    control window: all three fire during a short run (counted by the
    shared schedule) and training still completes."""
    import jax

    from repro.core.checkpoint.manager import CheckpointManager
    from repro.training import train_loop
    b, tc, cc, data = _smoke_setup(str(tmp_path / "ck"))
    faults = FaultSchedule.drop_at("manifest-init", occurrence=10 ** 9) \
        .chain(FaultSchedule.drop_at("manifest-dense", occurrence=10 ** 9)) \
        .chain(FaultSchedule.drop_at("tier_e.between-apply-and-manifest",
                                     occurrence=10 ** 9))
    init_fn, _, _, _ = train_loop.make_step_fns(b.model, tc)
    st0 = init_fn(jax.random.PRNGKey(tc.seed))
    mgr = CheckpointManager(b.model, cc, embed_init=st0["embed"],
                            faults=faults)
    train_loop.train(b.model, tc, data, 3, relaxed=True, state=st0,
                     ckpt_manager=mgr)
    mgr.flush()
    for point in ("manifest-init", "manifest-dense",
                  "tier_e.between-apply-and-manifest"):
        assert faults.counts.get(point, 0) >= 1, point
    mgr.pool.close()


def test_rollback_point_fires_on_recovery(tmp_path):
    """Crash between the mirror apply and the manifest advance: recovery
    finds a COMMITted entry newer than the manifest and rolls it back
    through the named ``rollback`` barrier."""
    import jax

    from repro.core.checkpoint import recovery
    from repro.core.checkpoint.manager import CheckpointManager
    from repro.training import train_loop
    tmp = str(tmp_path / "ck")
    b, tc, cc, data = _smoke_setup(tmp, dense_interval=0)
    faults = FaultSchedule.crash_at("tier_e.between-apply-and-manifest",
                                    occurrence=4)
    init_fn, _, _, _ = train_loop.make_step_fns(b.model, tc)
    st0 = init_fn(jax.random.PRNGKey(tc.seed))
    mgr = CheckpointManager(b.model, cc, embed_init=st0["embed"],
                            faults=faults)
    with pytest.raises(InjectedCrash):
        train_loop.train(b.model, tc, data, 6, relaxed=True, state=st0,
                         ckpt_manager=mgr)
    mgr.pool.close()
    dev = PmemPool.open(os.path.join(tmp, "pool.img"))
    sched = FaultSchedule.drop_at("rollback", occurrence=10 ** 9)
    dev.faults = sched                          # pure occurrence counter
    rec = recovery.recover(tmp, pool=dev)
    assert rec.rolled_back and rec.mirror_step == 2
    assert sched.counts.get("rollback", 0) >= 1
    dev.close()
