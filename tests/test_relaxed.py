"""The paper's central claim (Fig. 8): relaxed embedding lookup is exactly
equivalent to the dependent schedule — commutativity of the additive row
update. Property-tested across archs, seeds and optimizers."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the optional dev dep
from hypothesis import given, settings, strategies as st

from repro.configs import get_arch
from repro.configs.base import TrainConfig
from repro.core import relaxed as rx
from repro.data.synthetic import make_batches
from repro.training import train_loop


def run_pair(arch_id, steps=4, seed=0, embed_opt="sgd", lr=0.05):
    tc = TrainConfig(embed_learning_rate=lr, embed_optimizer=embed_opt)
    b = get_arch(arch_id, smoke=True)
    data = make_batches(b.model, 4, 16, seed=seed)
    _, l_strict = train_loop.train(b.model, tc, data, steps, relaxed=False)
    _, l_relax = train_loop.train(b.model, tc, data, steps, relaxed=True)
    return np.asarray(l_strict), np.asarray(l_relax)


@pytest.mark.parametrize("arch_id", ["tinyllama-1.1b", "rwkv6-3b",
                                     "whisper-base"])
def test_lm_bitwise_equivalence(arch_id):
    """Row-gather models: gather commutes with the update EXACTLY."""
    s, r = run_pair(arch_id)
    assert np.array_equal(s, r), (arch_id, s, r)


def test_dlrm_bag_equivalence():
    """Bag models: reduce order differs -> float-sum tolerance."""
    s, r = run_pair("dlrm-rm1", steps=5)
    np.testing.assert_allclose(s, r, rtol=2e-5, atol=2e-5)


@settings(deadline=None, max_examples=6)
@given(seed=st.integers(0, 100), lr=st.sampled_from([0.01, 0.1, 0.5]))
def test_property_equivalence_tinyllama(seed, lr):
    s, r = run_pair("tinyllama-1.1b", steps=3, seed=seed, lr=lr)
    assert np.array_equal(s, r)


@settings(deadline=None, max_examples=4)
@given(seed=st.integers(0, 50))
def test_property_equivalence_rowwise_adagrad(seed):
    """Adagrad's row update is a pure elementwise function of (grad, acc):
    gather still commutes -> exact for LMs."""
    s, r = run_pair("tinyllama-1.1b", steps=3, seed=seed,
                    embed_opt="rowwise_adagrad")
    np.testing.assert_allclose(s, r, rtol=1e-6, atol=1e-6)


def test_prefetch_identity_algebra():
    """gather(T + U, idx) == gather(T, idx) + gather(U, idx) exactly."""
    key = jax.random.PRNGKey(0)
    T = jax.random.normal(key, (128, 16), jnp.float32)
    U = jax.random.normal(jax.random.PRNGKey(1), (128, 16), jnp.float32) * 0.1
    idx = jax.random.randint(jax.random.PRNGKey(2), (4, 7), 0, 128)
    embed = {"table": T}
    upd = {"table": U}
    cfg = get_arch("tinyllama-1.1b", smoke=True).model
    batch = {"tokens": idx}
    got = rx.prefetch_corrected(embed, upd, cfg, batch)
    want = rx.lookup_rows(rx.apply_embed_update(embed, upd), cfg, batch)
    assert jnp.array_equal(got, want)


def test_consecutive_overlap_zipf():
    """Zipf sparse features -> high consecutive-batch overlap (the RAW
    hazard premise: paper cites ~80%)."""
    cfg = get_arch("dlrm-rm1", smoke=True).model
    data = make_batches(cfg, 64, 0, seed=0)
    a, b = data.next(0), data.next(1)
    frac = float(rx.consecutive_overlap(cfg, a, b))
    assert frac > 0.5, frac


def test_touched_indices_known_in_advance():
    """Batch-aware property: indices come from the data pipeline before any
    compute (enables background undo logging)."""
    from repro.data.lookahead import LookaheadIterator
    cfg = get_arch("dlrm-rm1", smoke=True).model
    it = LookaheadIterator(make_batches(cfg, 4, 0), cfg, depth=3)
    idx_next = np.asarray(it.peek_indices(1))
    batch_next = it.peek(1)
    assert np.array_equal(idx_next, np.asarray(batch_next["sparse"]))
