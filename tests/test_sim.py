"""Simulator must reproduce the paper's headline claims within tolerance."""
import numpy as np
import pytest

from repro.sim.energy import energy_table
from repro.sim.engine import SYSTEMS, simulate
from repro.sim.models_rm import RMS


@pytest.fixture(scope="module")
def times():
    return {rm: {s: simulate(s, w).batch_time for s in SYSTEMS[:-1]}
            for rm, w in RMS.items()}


def test_system_ordering(times):
    """SSD >> PMEM > PCIe >= CXL-D; CXL fastest (paper Fig. 11)."""
    for rm, t in times.items():
        assert t["SSD"] > 3 * t["PMEM"], rm
        assert t["PMEM"] > t["PCIe"] * 0.99, rm
        assert t["PCIe"] >= t["CXL-D"] * 0.999, rm
        assert t["CXL"] == min(t.values()), rm


def test_claim_5_2x_speedup(times):
    avg = np.mean([times[r]["PMEM"] / times[r]["CXL"] for r in RMS])
    assert 4.2 <= avg <= 6.2, avg      # paper: 5.2x


def test_claim_cxl_d_vs_pcie(times):
    avg = np.mean([1 - times[r]["CXL-D"] / times[r]["PCIe"] for r in RMS])
    assert 0.10 <= avg <= 0.35, avg    # paper: 23%


def test_claim_relaxation_gain(times):
    avg = np.mean([1 - times[r]["CXL"] / times[r]["CXL-B"] for r in RMS])
    assert 0.07 <= avg <= 0.25, avg    # paper: 14%


def test_claim_energy_76pct():
    t = energy_table()
    sav = np.mean([1 - t[r]["CXL"] for r in t])
    assert 0.66 <= sav <= 0.86, sav    # paper: 76%


def test_energy_dram_vs_pmem_direction():
    """Embedding-intensive RMs: DRAM costs more than PMEM (density/static
    power); paper Fig. 13 discussion."""
    t = energy_table()
    assert t["RM1"]["DRAM"] > 1.0
    assert t["RM2"]["DRAM"] > 1.0


def test_breakdown_fields(times):
    r = simulate("CXL-B", RMS["RM1"])
    assert set(r.breakdown) == {"B-MLP", "T-MLP", "Embedding", "Transfer",
                                "Checkpoint"}
    assert r.batch_time > 0
    assert all(seg.end >= seg.start for seg in r.trace)


def test_relaxed_checkpoint_hidden():
    """CXL's exposed checkpoint must be smaller than CXL-D's everywhere and
    near-fully hidden on MLP-bound RMs (long idle windows)."""
    for rm, w in RMS.items():
        d = simulate("CXL-D", w).breakdown["Checkpoint"]
        c = simulate("CXL", w).breakdown["Checkpoint"]
        assert c <= d * 0.8 + 1e-9, rm
    for rm in ("RM3", "RM4"):
        d = simulate("CXL-D", RMS[rm]).breakdown["Checkpoint"]
        c = simulate("CXL", RMS[rm]).breakdown["Checkpoint"]
        assert c <= d * 0.2 + 1e-9, rm
