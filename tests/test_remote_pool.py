"""Remote memory-node backend: wire-protocol correctness and nastiness
(truncated/oversized frames, server restart mid-op), multi-tenant domains
(namespaces, quotas, isolation), per-tenant metrics attribution, nmp-over-
the-wire parity, and checkpoint-manager recovery against a surviving server
after trainer death."""
import os
import socket
import struct
import threading

import numpy as np
import pytest

from repro.pool import (DramPool, FaultSchedule, InjectedCrash, NmpQueue,
                        PmemPool, PoolAllocator, PoolAuthError,
                        PoolConnectionError, PoolError, PoolServer,
                        QuotaExceededError, RemotePool,
                        TenantIsolationError, make_pool, parse_addr)
from repro.pool.remote import recv_frame, send_frame

# CI matrixes pool-side compression over {none, zlib}; the fused-path
# and scan tests must exercise whichever mode the cell selects
COMPRESS = os.environ.get("REPRO_POOL_COMPRESS", "zlib")


@pytest.fixture
def server(tmp_path):
    srv = PoolServer(DramPool(1 << 18),
                     f"unix:{tmp_path}/pool.sock").start()
    yield srv
    srv.shutdown(close_device=True)


def connect(srv, tenant="default", quota=0):
    return RemotePool(srv.addr, tenant=tenant, quota=quota, timeout=20.0)


# -- basic device semantics over the wire ------------------------------------

def test_roundtrip_persist_crash(server, rng):
    dev = connect(server)
    a = PoolAllocator(dev)
    r = a.domain("d").alloc("x", shape=(16, 4), dtype="float32")
    v1 = rng.standard_normal((16, 4)).astype(np.float32)
    r.write_array(v1)
    r.persist(point="p")
    r.write_array(v1 * 2)                   # never persisted
    np.testing.assert_array_equal(r.read_array(), v1 * 2)
    dev.crash()                             # node power-cycle
    np.testing.assert_array_equal(r.read_array(), v1)
    assert dev.metrics.crashes == 1
    # idempotent reopen via a second connection sees the same region
    dev2 = connect(server)
    r2 = PoolAllocator(dev2).domain("d").get("x")
    assert r2 is not None and r2.off == r.off
    np.testing.assert_array_equal(r2.read_array(), v1)


def test_make_pool_remote(server):
    dev = make_pool("remote", addr=server.addr, tenant="t")
    assert dev.backend == "remote" and dev.capacity > 0
    with pytest.raises(PoolError):
        make_pool("remote")                 # no addr
    dev.close()
    with pytest.raises(PoolError):
        dev.read(0, 1)                      # closed client device


def test_nmp_over_wire_matches_numpy(server, rng):
    dev = connect(server, tenant="nmp")
    a = PoolAllocator(dev)
    tab = rng.standard_normal((32, 8)).astype(np.float32)
    r = a.domain("emb").alloc("t", shape=tab.shape, dtype="float32")
    r.write_array(tab)
    q = NmpQueue(dev)
    idx = np.array([3, 31, 0, 3])
    np.testing.assert_array_equal(q.gather(r, idx), tab[idx])
    bags = rng.integers(0, 32, (5, 4))
    np.testing.assert_allclose(q.bag_gather(r, bags), tab[bags].sum(1),
                               rtol=1e-6)
    old = q.undo_snapshot(r, np.array([1, 2]))
    np.testing.assert_array_equal(old, tab[[1, 2]])
    q.row_update(r, np.array([1, 2]), np.ones((2, 8), np.float32),
                 point="apply")
    dev.crash()                             # row_update persisted
    np.testing.assert_array_equal(r.read_array()[[1, 2]],
                                  np.ones((2, 8), np.float32))
    before = r.read_array().copy()
    q.scatter_add(r, np.array([0, 0, 5]), np.ones((3, 8), np.float32))
    exp = before.copy()
    np.add.at(exp, [0, 0, 5], np.ones((3, 8), np.float32))
    np.testing.assert_allclose(r.read_array(), exp, rtol=1e-6)
    # near-memory accounting happened server-side, attributed to this tenant
    m = dev.metrics
    assert m.media_bytes("bag_gather") > 0 and m.ndp_time_s > 0
    assert m.link_bytes() > 0


def test_faults_armed_over_wire(server):
    dev = connect(server)
    a = PoolAllocator(dev)
    r = a.domain("d").alloc("x", shape=(1024,), dtype="float32")
    r.write_array(np.zeros(1024, np.float32))
    r.persist(point="init")
    dev.faults = FaultSchedule.torn_at("apply", occurrence=1)
    r.write_array(np.full(1024, 3.0, np.float32))
    with pytest.raises(InjectedCrash):
        r.persist(point="apply")
    dev.faults = None
    dev.crash()
    v = r.read_array()
    assert (v == 3.0).any() and (v == 0.0).any()    # the classic torn write
    assert dev.metrics.torn_writes == 1


# -- multi-tenant domains ----------------------------------------------------

def test_tenant_namespaces_are_disjoint(server, rng):
    a = connect(server, tenant="a")
    b = connect(server, tenant="b")
    ra = PoolAllocator(a).domain("emb").alloc("t", shape=(8,),
                                              dtype="float32")
    rb = PoolAllocator(b).domain("emb").alloc("t", shape=(16,),
                                              dtype="float32")
    # same domain/name, different tenants -> different regions
    assert (ra.off, ra.nbytes) != (rb.off, rb.nbytes)
    va = rng.standard_normal(8).astype(np.float32)
    vb = rng.standard_normal(16).astype(np.float32)
    ra.write_array(va)
    rb.write_array(vb)
    np.testing.assert_array_equal(ra.read_array(), va)
    np.testing.assert_array_equal(rb.read_array(), vb)
    # b's directory view has no sight of a's regions beyond its own
    assert PoolAllocator(b).domain("emb").get("t").nbytes == rb.nbytes


def test_cross_tenant_access_denied(server, rng):
    a = connect(server, tenant="a")
    ra = PoolAllocator(a).domain("emb").alloc("t", shape=(64,),
                                              dtype="float32")
    ra.write_array(rng.standard_normal(64).astype(np.float32))
    eve = connect(server, tenant="eve")
    with pytest.raises(TenantIsolationError):
        eve.read(ra.off, ra.nbytes)
    with pytest.raises(TenantIsolationError):
        eve.write(ra.off, np.zeros(8, np.uint8))
    with pytest.raises(TenantIsolationError):
        eve.persist(ra.off, ra.nbytes, point="steal")
    with pytest.raises(TenantIsolationError):
        NmpQueue(eve).gather(ra, np.array([0]))
    with pytest.raises(TenantIsolationError):
        eve.read(0, 64)                     # the superblock is nobody's
    # eve's own allocations still work, and freeing her domain frees hers
    re = PoolAllocator(eve).domain("emb").alloc("t", shape=(4,),
                                                dtype="float32")
    assert re.off != ra.off
    assert PoolAllocator(eve).free_domain("emb")
    assert PoolAllocator(eve).domain("emb").get("t") is None
    # a's domain is untouched by eve's free
    assert PoolAllocator(a).domain("emb").get("t").off == ra.off


def test_quota_enforced_and_idempotent(server):
    dev = connect(server, tenant="q", quota=1 << 12)
    a = PoolAllocator(dev)
    r = a.domain("d").alloc("x", shape=(1 << 10,), dtype="uint8")  # 1K of 4K
    with pytest.raises(QuotaExceededError):
        a.domain("d").alloc("big", shape=(1 << 13,), dtype="uint8")
    # idempotent reopen of an existing region never double-counts
    r2 = a.domain("d").alloc("x", shape=(1 << 10,), dtype="uint8")
    assert r2.off == r.off
    a.domain("d").alloc("y", shape=(1 << 10,), dtype="uint8")  # still fits


def test_per_tenant_metrics_attribution(server, rng):
    a = connect(server, tenant="worker-a")
    b = connect(server, tenant="worker-b")
    ra = PoolAllocator(a).domain("d").alloc("x", shape=(256,),
                                            dtype="float32")
    ra.write_array(rng.standard_normal(256).astype(np.float32))
    ra.persist(point="p")
    snaps = a.metrics_snapshot(scope="all")
    assert snaps["worker-a"]["media_bytes"] > 0
    assert snaps["worker-b"]["media_bytes"] == 0   # b did nothing
    assert b.metrics.media_bytes() == 0


# -- protocol nastiness ------------------------------------------------------

def _raw_connect(srv):
    s = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    s.settimeout(10.0)
    s.connect(srv.addr[5:])
    return s


def test_oversized_frame_rejected_not_hung(server):
    s = _raw_connect(server)
    s.sendall(struct.pack("<I", (1 << 30) + 1))    # absurd length prefix
    resp = recv_frame(s)
    assert resp is not None and resp[0]["kind"] == "WireError"
    s.close()
    # the server survives and serves new connections
    assert connect(server).capacity > 0


def test_truncated_frame_drops_connection_cleanly(server):
    s = _raw_connect(server)
    s.sendall(struct.pack("<I", 64) + b"\x00\x01")  # promise 64, send 2
    s.close()                                       # EOF mid-frame
    assert connect(server).capacity > 0             # server unharmed


def test_garbage_header_is_typed_error(server):
    s = _raw_connect(server)
    body = b"\xde\xad\xbe\xef"
    s.sendall(struct.pack("<I", 4 + len(body)) + struct.pack("<I", 4) + body)
    resp = recv_frame(s)
    assert resp is not None and resp[0]["kind"] == "WireError"
    s.close()


def test_op_before_hello_denied(server):
    s = _raw_connect(server)
    send_frame(s, {"op": "read", "off": 0, "nbytes": 8, "tag": "r"})
    hdr, _ = recv_frame(s)
    assert hdr["ok"] is False and hdr["kind"] == "TenantIsolationError"
    s.close()


def test_connection_refused_is_typed(tmp_path):
    with pytest.raises(PoolConnectionError):
        RemotePool(f"unix:{tmp_path}/nobody.sock", timeout=5.0)


def test_server_restart_mid_op(tmp_path, rng):
    """A dying server surfaces as PoolConnectionError, never a hang; a
    pmem-backed server that restarts serves the durable state back."""
    img = str(tmp_path / "pool.img")
    srv = PoolServer(PmemPool(img, 1 << 18),
                     f"unix:{tmp_path}/pool.sock").start()
    dev = connect(srv, tenant="t")
    r = PoolAllocator(dev).domain("d").alloc("x", shape=(32,),
                                             dtype="float32")
    v = rng.standard_normal(32).astype(np.float32)
    r.write_array(v)
    r.persist(point="p")
    srv.shutdown(close_device=True)         # node dies mid-session
    with pytest.raises(PoolConnectionError):
        r.read_array()
    # node restarts over the same durable image
    srv2 = PoolServer(PmemPool.open(img),
                      f"unix:{tmp_path}/pool.sock").start()
    try:
        dev2 = connect(srv2, tenant="t")
        r2 = PoolAllocator(dev2).domain("d").get("x")
        assert r2 is not None
        np.testing.assert_array_equal(r2.read_array(), v)
    finally:
        srv2.shutdown(close_device=True)


def test_concurrent_tenants_hammer(server, rng):
    """Several client threads over one node: no cross-talk, no deadlock."""
    errs = []

    def work(name):
        try:
            dev = connect(server, tenant=name)
            r = PoolAllocator(dev).domain("d").alloc(
                "x", shape=(128,), dtype="float32")
            for i in range(20):
                v = np.full(128, float(i), np.float32)
                r.write_array(v)
                r.persist(point="p")
                np.testing.assert_array_equal(r.read_array(), v)
            dev.close()
        except Exception as e:              # surfaced in the main thread
            errs.append((name, e))

    threads = [threading.Thread(target=work, args=(f"t{i}",))
               for i in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join(timeout=60)
    assert not errs, errs


# -- server-side undo capture: the link-traffic acceptance tests -------------

def test_fused_undo_append_keeps_old_rows_off_link(server, rng):
    """Tier-E acceptance: the fused op ships only (step, idx, new_rows)
    over the wire; the undo image (old rows) is captured, compressed and
    committed entirely inside the memory node."""
    from repro.core.checkpoint.undo_log import UndoRing

    dev = connect(server, tenant="fused")
    a = PoolAllocator(dev)
    tab = rng.standard_normal((256, 16)).astype(np.float32)
    mirror = a.domain("m").alloc("rows", shape=tab.shape, dtype="float32")
    mirror.write_array(tab)
    mirror.persist(point="load")
    ring = UndoRing(a, max_logs=4, compress=COMPRESS)
    idx = np.unique(rng.integers(0, 256, 64))
    new0 = rng.standard_normal((idx.size, 16)).astype(np.float32)
    ring.log_and_apply(0, mirror, idx, new0)        # warmup: ring creation
    dev.reset_metrics()

    new1 = rng.standard_normal((idx.size, 16)).astype(np.float32)
    info = ring.log_and_apply(1, mirror, idx, new1)
    m = dev.metrics
    # per-step link bytes <= idx + new_rows + O(header)
    assert m.link_bytes() <= idx.nbytes + new1.nbytes + 1024
    # ...while media still carries the full undo payload: the capture read
    # and the (compressed) log write, plus the apply
    assert m.media_bytes("undo_snapshot") == idx.size * 16 * 4
    assert m.media_bytes("undo") >= info["stored"]
    assert m.media_bytes() > m.link_bytes()
    # the logged image is the step-0 state (new0), bit-exact after decompress
    got_idx, got_rows, _ = ring.read(1)
    np.testing.assert_array_equal(got_idx, idx)
    np.testing.assert_array_equal(got_rows, new0)
    np.testing.assert_array_equal(mirror.read_array()[idx], new1)


def test_manager_tier_e_link_bytes_bounded(tmp_path, rng):
    """End-to-end acceptance: a remote tier-E step (fused op + manifest +
    GC scan) stays within idx+new_rows+O(headers) of link traffic, while
    media bytes keep the undo payloads."""
    import jax

    from repro.configs import get_arch
    from repro.configs.base import CheckpointConfig, TrainConfig
    from repro.core.checkpoint.manager import CheckpointManager
    from repro.training import train_loop

    srv = PoolServer(DramPool(1 << 22),
                     f"unix:{tmp_path}/pool.sock").start()
    try:
        cc = CheckpointConfig(directory=str(tmp_path / "ck"),
                              dense_interval=0, pool_backend="remote",
                              pool_addr=srv.addr, pool_tenant="trainer",
                              pool_compress=COMPRESS)
        b = get_arch("tinyllama-1.1b", smoke=True)
        tc = TrainConfig(checkpoint=cc)
        init_fn, _, _, _ = train_loop.make_step_fns(b.model, tc)
        st0 = init_fn(jax.random.PRNGKey(0))
        mgr = CheckpointManager(b.model, cc, embed_init=st0["embed"])
        d = mgr.mirror_region.shape[-1]
        nrows = mgr.mirror_region.shape[0]
        idx = np.unique(rng.integers(0, nrows, 32)).astype(np.int64)
        new = rng.standard_normal((idx.size, d)).astype(np.float32)
        mgr._do_tier_e(0, idx, new)                 # warmup (ring creation)
        mgr.pool.reset_metrics()
        sent = 0
        for step in (1, 2, 3):
            mgr._do_tier_e(step, idx, new)
            sent += idx.nbytes + new.nbytes
        m = mgr.pool.metrics
        # O(header) covers the fused-op header + the one-round-trip GC
        # header scan (nslots * 48B), never the row payloads
        assert m.link_bytes() <= sent + 3 * 4096
        assert m.media_bytes("undo_snapshot") == 3 * idx.size * d * 4
        assert m.media_bytes() > 2 * m.link_bytes()
        assert mgr.stats["undo_stored_bytes"] <= mgr.stats["undo_raw_bytes"]
        mgr.pool.close()
    finally:
        srv.shutdown(close_device=True)


def test_committed_scan_is_single_round_trip(server, rng):
    """The batched header scan: committed_steps()/gc() cost O(1) wire
    round-trips, not one per slot."""
    from repro.core.checkpoint.undo_log import UndoRing

    dev = connect(server, tenant="scan")
    ring = UndoRing(PoolAllocator(dev), max_logs=16,
                    compress=COMPRESS)
    for s in range(5):
        ring.append(s, np.arange(4) + s, np.ones((4, 8), np.float32))
    calls = []
    orig = dev._request

    def counting(hdr, body=b""):
        calls.append(hdr["op"])
        return orig(hdr, body)

    dev._request = counting
    try:
        assert ring.committed_steps() == [0, 1, 2, 3, 4]
        assert len(calls) == 1, f"scan used {len(calls)} RTTs: {calls}"
        calls.clear()
        # the writer tracked every append, so gc needs no scan at all:
        # ONE batched slot_clear is the whole round trip
        ring.gc(keep_from=2)
        assert len(calls) == 1, f"gc used {len(calls)} RTTs: {calls}"
    finally:
        dev._request = orig
    assert ring.committed_steps() == [2, 3, 4]


def test_gc_round_trips_constant_in_expired_count(server, rng):
    """GC acceptance: O(1) wire round-trips however many slots expired —
    the per-slot commit-clears ride in one ``slot_clear`` op."""
    from repro.core.checkpoint.undo_log import UndoRing

    dev = connect(server, tenant="gcbatch")
    ring = UndoRing(PoolAllocator(dev), max_logs=24, compress=COMPRESS)
    for s in range(20):
        ring.append(s, np.arange(4) + s, np.ones((4, 8), np.float32))
    calls = []
    orig = dev._request

    def counting(hdr, body=b""):
        calls.append(hdr["op"])
        return orig(hdr, body)

    dev._request = counting
    try:
        ring.gc(keep_from=19)                # 19 expired entries, 1 RTT
        assert len(calls) == 1, f"gc used {len(calls)} RTTs: {calls}"
        calls.clear()
        ring.gc(keep_from=19)                # nothing expired: NO wire op
        assert len(calls) == 0, f"empty gc used {len(calls)} RTTs: {calls}"
    finally:
        dev._request = orig
    assert ring.committed_steps() == [19]
    # a fresh attach (recovery) lost the liveness map: the first gc pays
    # ONE rebuild scan, then clears in one batched op — still O(1)
    ring2 = UndoRing(PoolAllocator(dev), max_logs=24, compress=COMPRESS)
    calls.clear()
    dev._request = counting
    try:
        ring2.gc(keep_from=20)
        assert calls == ["nmp", "nmp"], f"rebuild gc RTTs: {calls}"
    finally:
        dev._request = orig
    assert ring2.committed_steps() == []


def test_free_region_over_wire_releases_quota(server):
    dev = connect(server, tenant="fr", quota=1 << 12)
    a = PoolAllocator(dev)
    a.domain("d").alloc("x", shape=(1 << 10,), dtype="uint8")
    a.domain("d").alloc("y", shape=(1 << 10,), dtype="uint8")
    with pytest.raises(QuotaExceededError):
        a.domain("d").alloc("z", shape=(1 << 11) + 1024, dtype="uint8")
    assert a.domain("d").free_region("x")        # free-then-alloc fits
    a.domain("d").alloc("z", shape=(1 << 11,), dtype="uint8")
    assert a.domain("d").get("x") is None


# -- checkpoint stack against a surviving node --------------------------------

def test_manager_recovery_survives_trainer_death(tmp_path):
    """The acceptance drill, in-process: a trainer checkpoints into a live
    pool-server, dies without any cleanup, and a fresh process-equivalent
    (new connection) recovers bit-identically and resumes exactly."""
    import jax

    from repro.configs import get_arch
    from repro.configs.base import CheckpointConfig, TrainConfig
    from repro.core.checkpoint import recovery
    from repro.core.checkpoint.manager import CheckpointManager
    from repro.data.synthetic import make_batches
    from repro.training import train_loop

    srv = PoolServer(PmemPool(str(tmp_path / "pool.img"), 1 << 22),
                     f"unix:{tmp_path}/pool.sock").start()
    try:
        ck = str(tmp_path / "ck")
        cc = CheckpointConfig(directory=ck, dense_interval=1,
                              pool_backend="remote", pool_addr=srv.addr,
                              pool_tenant="trainer")
        b = get_arch("tinyllama-1.1b", smoke=True)
        tc = TrainConfig(embed_learning_rate=0.05, checkpoint=cc)
        data = make_batches(b.model, 4, 16, seed=3)
        init_fn, _, _, _ = train_loop.make_step_fns(b.model, tc)
        _, full = train_loop.train(b.model, tc, data, 8, relaxed=True)

        st0 = init_fn(jax.random.PRNGKey(tc.seed))
        mgr = CheckpointManager(b.model, cc, embed_init=st0["embed"])
        train_loop.train(b.model, tc, data, 5, relaxed=True, state=st0,
                         ckpt_manager=mgr)
        mgr.flush()
        mirror_before = np.array(mgr.mirror_rows)
        # trainer death: the socket just vanishes, no flush/close handshake
        mgr.pool._sock.close()
        mgr.pool.closed = True

        rec = recovery.recover(ck)          # reconnects via POOL.json
        assert rec.mirror_step == 4 and rec.dense_step == 4
        np.testing.assert_array_equal(rec.embed_rows, mirror_before)
        fresh = init_fn(jax.random.PRNGKey(tc.seed))
        st, resume = recovery.resume_train_state(rec, fresh)
        assert resume == 5
        _, tail = train_loop.train(b.model, tc, data, 3, relaxed=True,
                                   state=st, start_step=resume)
        np.testing.assert_allclose(np.asarray(tail), np.asarray(full[5:]),
                                   rtol=1e-6, atol=1e-6)
        rec.pool.close()
    finally:
        srv.shutdown(close_device=True)


# -- shared-secret auth (tcp transport) --------------------------------------


@pytest.fixture
def secure_tcp_server():
    srv = PoolServer(DramPool(1 << 18), "tcp:127.0.0.1:0",
                     secret="hunter2").start()
    yield srv
    srv.shutdown(close_device=True)


def test_tcp_auth_good_secret_round_trips(secure_tcp_server, rng):
    """The HMAC challenge handshake admits the right secret and the
    connection then behaves exactly like an unauthenticated one."""
    dev = RemotePool(secure_tcp_server.addr, tenant="t", timeout=20.0,
                     secret="hunter2")
    r = PoolAllocator(dev).domain("d").alloc("x", shape=(8, 4),
                                             dtype="float32")
    v = rng.standard_normal((8, 4)).astype(np.float32)
    r.write_array(v)
    r.persist(point="p")
    np.testing.assert_array_equal(r.read_array(), v)
    out = NmpQueue(dev).gather(r, np.array([1, 3]))
    np.testing.assert_array_equal(out, v[[1, 3]])
    dev.close()


def test_tcp_auth_wrong_secret_rejected(secure_tcp_server):
    with pytest.raises(PoolAuthError):
        RemotePool(secure_tcp_server.addr, tenant="t", timeout=20.0,
                   secret="wrong")


def test_tcp_auth_missing_secret_rejected(secure_tcp_server, monkeypatch):
    monkeypatch.delenv("REPRO_POOL_SECRET", raising=False)
    with pytest.raises(PoolAuthError):
        RemotePool(secure_tcp_server.addr, tenant="t", timeout=20.0)


def test_tcp_auth_secret_from_environment(secure_tcp_server, monkeypatch):
    """make_pool / recovery reconnects carry no secret argument — the env
    var (never POOL.json) supplies it."""
    monkeypatch.setenv("REPRO_POOL_SECRET", "hunter2")
    dev = make_pool("remote", addr=secure_tcp_server.addr, tenant="t")
    assert PoolAllocator(dev).domain("d").get("nothing") is None
    dev.close()


def test_unix_socket_exempt_from_secret(tmp_path):
    """Unix transports are filesystem-gated: a server started with a secret
    still admits local unix clients without a handshake."""
    srv = PoolServer(DramPool(1 << 18), f"unix:{tmp_path}/sec.sock",
                     secret="hunter2").start()
    try:
        dev = RemotePool(srv.addr, tenant="t", timeout=20.0)
        assert dev.capacity > 0
        dev.close()
    finally:
        srv.shutdown(close_device=True)


def test_auth_challenge_is_single_use_per_attempt(secure_tcp_server):
    """A replayed or transplanted proof fails: each hello attempt answers a
    fresh nonce, and the proof binds the tenant name."""
    from repro.pool.remote import auth_proof
    kind, target = parse_addr(secure_tcp_server.addr)
    s = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
    s.settimeout(20.0)
    s.connect(target)
    send_frame(s, {"op": "hello", "tenant": "a"})
    hdr, _ = recv_frame(s)
    assert hdr["kind"] == "PoolAuthError" and hdr["challenge"]
    # right secret, wrong tenant binding -> rejected
    proof = auth_proof("hunter2", hdr["challenge"], "someone-else")
    send_frame(s, {"op": "hello", "tenant": "a",
                   "challenge": hdr["challenge"], "auth": proof})
    hdr2, _ = recv_frame(s)
    assert not hdr2.get("ok") and hdr2["kind"] == "PoolAuthError"
    # the old nonce is dead: replaying the correct computation now fails too
    good = auth_proof("hunter2", hdr["challenge"], "a")
    send_frame(s, {"op": "hello", "tenant": "a",
                   "challenge": hdr["challenge"], "auth": good})
    hdr3, _ = recv_frame(s)
    assert not hdr3.get("ok") and hdr3["kind"] == "PoolAuthError"
    s.close()
