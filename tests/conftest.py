import os
import sys

# tests see the real device count (the 512-device override is dry-run only)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def assert_finite(x, name="x"):
    import jax.numpy as jnp
    assert bool(jnp.isfinite(x).all()), f"{name} contains non-finite values"
