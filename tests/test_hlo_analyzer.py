"""The roofline's HLO analyzer must agree with XLA cost analysis on unrolled
programs and correctly multiply scan bodies by trip count."""
import jax
import jax.numpy as jnp

from repro.utils.hlo import analyze, parse_hlo


def _compile(f, *args):
    return jax.jit(f).lower(*args).compile()


def test_scan_equals_unrolled_flops():
    L, B, D = 7, 32, 64
    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((B, D), jnp.float32)

    def scan_model(ws, x):
        def body(x, w):
            return jnp.tanh(x @ w), None
        return jax.lax.scan(body, x, ws)[0].sum()

    def unrolled(ws, x):
        for i in range(L):
            x = jnp.tanh(x @ ws[i])
        return x.sum()

    a = analyze(_compile(scan_model, ws, x).as_text())
    b = analyze(_compile(unrolled, ws, x).as_text())
    expect = 2 * L * B * D * D
    assert a["flops"] == expect, a["flops"]
    assert b["flops"] == expect, b["flops"]


def test_grad_with_remat_flops():
    L, B, D = 5, 16, 32
    ws = jax.ShapeDtypeStruct((L, D, D), jnp.float32)
    x = jax.ShapeDtypeStruct((B, D), jnp.float32)

    def loss(ws, x):
        def body(x, w):
            return jnp.tanh(x @ w), None
        y = jax.lax.scan(jax.checkpoint(body), x, ws)[0]
        return (y * y).sum()

    a = analyze(_compile(jax.grad(loss), ws, x).as_text())
    # fwd + recomputed fwd + 2 bwd dots per layer = 4 dots/layer
    expect = 4 * 2 * L * B * D * D
    assert abs(a["flops"] - expect) / expect < 0.01, a["flops"]


def test_dus_counted_as_slice():
    """In-place cache update: bytes ~ row, not the full buffer."""
    cache = jax.ShapeDtypeStruct((1024, 1024), jnp.float32)
    row = jax.ShapeDtypeStruct((1, 1024), jnp.float32)

    def f(cache, row):
        def body(c, i):
            return jax.lax.dynamic_update_slice(c, row, (i, 0)), None
        return jax.lax.scan(body, cache, jnp.arange(64))[0]

    a = analyze(_compile(f, cache, row).as_text())
    full = 64 * 1024 * 1024 * 4        # if DUS were counted at buffer size
    assert a["bytes"] < full * 0.2, a["bytes"]


def test_collectives_with_trips():
    if jax.device_count() < 2:
        import pytest
        pytest.skip("needs >= 2 devices (dry-run only)")


def test_parse_robustness():
    comps, entry = parse_hlo("")
    assert comps == {} and entry is None
    a = analyze("")
    assert a["flops"] == 0
