"""repro.pool: device persistence/crash semantics, allocator directory
recovery, near-memory ops + traffic accounting, deterministic fault
injection, the embedding_ops `pool` strategy, and sim-engine calibration.

The backend-parametrized tests honor REPRO_POOL_BACKENDS (comma list;
default "dram,pmem"). CI's pool-backends job adds "remote", running the same
semantics through an in-process pool-server over a Unix socket."""
import os

import numpy as np
import pytest

from repro.core.checkpoint.undo_log import UndoRing
from repro.pool import (DramPool, EmbeddingPoolMirror, FaultEvent,
                        FaultSchedule, InjectedCrash, JsonRegion, NmpQueue,
                        PmemPool, PoolAllocator, PoolError, PoolServer,
                        RemotePool, ShardedPool, make_pool)
from repro.pool import compress as pc
from repro.pool import undo_codec as uc

BACKENDS = [b.strip() for b in os.environ.get(
    "REPRO_POOL_BACKENDS", "dram,pmem").split(",") if b.strip()]
# default compression for UndoRings built here (tests that pin a mode
# parametrize it explicitly); CI matrixes this over {none, zlib}
COMPRESS = os.environ.get("REPRO_POOL_COMPRESS", "zlib")

_SOCK_SEQ = [0]


def mkpool(backend, tmp_path, capacity=1 << 18, faults=None):
    if backend == "dram":
        return DramPool(capacity, faults=faults)
    if backend == "pmem":
        return PmemPool(str(tmp_path / "pool.img"), capacity, faults=faults)
    if backend == "remote":
        _SOCK_SEQ[0] += 1
        srv = PoolServer(DramPool(capacity),
                         f"unix:{tmp_path}/p{_SOCK_SEQ[0]}.sock").start()
        dev = RemotePool(srv.addr)
        dev._test_server = srv     # keep the node alive with the device
        if faults is not None:
            dev.faults = faults
        return dev
    if backend == "sharded":
        # two in-process memory nodes behind one device: the full suite
        # must hold with domains spread over several servers
        _SOCK_SEQ[0] += 1
        seq = _SOCK_SEQ[0]
        srvs = [PoolServer(DramPool(capacity),
                           f"unix:{tmp_path}/p{seq}s{i}.sock").start()
                for i in range(2)]
        dev = ShardedPool([s.addr for s in srvs])
        dev._test_servers = srvs   # keep the nodes alive with the device
        if faults is not None:
            dev.faults = faults
        return dev
    raise ValueError(f"unknown backend {backend!r}")


# -- device ------------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_persist_survives_crash_unpersisted_lost(backend, tmp_path, rng):
    dev = mkpool(backend, tmp_path)
    a = PoolAllocator(dev)
    r = a.domain("d").alloc("x", shape=(16, 4), dtype="float32")
    v1 = rng.standard_normal((16, 4)).astype(np.float32)
    r.write_array(v1)
    r.persist(point="p")
    v2 = v1 * 2
    r.write_array(v2)                       # never persisted
    np.testing.assert_array_equal(r.read_array(), v2)   # cache is coherent
    dev.crash()
    np.testing.assert_array_equal(r.read_array(), v1)   # durable image only
    # sharded counts one crash per power-cycled node (2-shard fixture)
    assert dev.metrics.crashes == (2 if backend == "sharded" else 1)


def test_pmem_reopen_across_handles(tmp_path, rng):
    path = str(tmp_path / "pool.img")
    dev = PmemPool(path, 1 << 18)
    a = PoolAllocator(dev)
    r = a.domain("d").alloc("x", shape=(8,), dtype="float32")
    v = rng.standard_normal(8).astype(np.float32)
    r.write_array(v)
    r.persist(point="p")
    dev.close()
    dev2 = PmemPool.open(path)              # like a power-cycled module
    r2 = PoolAllocator(dev2).domain("d").get("x")
    assert r2 is not None and r2.off == r.off
    np.testing.assert_array_equal(r2.read_array(), v)


def test_pool_grows_on_demand(tmp_path):
    dev = mkpool("pmem", tmp_path, capacity=1 << 17)
    a = PoolAllocator(dev)
    r = a.domain("d").alloc("big", shape=(1 << 20,), dtype="uint8")
    assert dev.capacity >= r.off + r.nbytes
    assert os.path.getsize(str(tmp_path / "pool.img")) == dev.capacity


def test_make_pool_validates():
    with pytest.raises(PoolError):
        make_pool("nvme")
    with pytest.raises(PoolError):
        make_pool("pmem")                   # needs a path


# -- allocator ---------------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_directory_survives_crash_mid_update(backend, tmp_path):
    # crash during the superblock persist of a *new* alloc: the previous
    # directory (A/B slot) must still be readable and list older regions.
    dev = mkpool(backend, tmp_path)
    a = PoolAllocator(dev)
    a.domain("d").alloc("first", shape=(4,), dtype="float32")
    dev.faults = FaultSchedule.torn_at("superblock", occurrence=1)
    with pytest.raises(InjectedCrash):
        a.domain("d").alloc("second", shape=(4,), dtype="float32")
    dev.faults = None
    dev.crash()
    a2 = PoolAllocator(dev)
    assert a2.domain("d").get("first") is not None


def test_json_region_ab_update(tmp_path):
    dev = mkpool("dram", tmp_path)
    a = PoolAllocator(dev)
    jr = JsonRegion.create(a.domain("meta"), "m", nbytes=4 << 10)
    assert jr.read() is None
    jr.write({"step": 1})
    jr.write({"step": 2})
    assert jr.read() == {"step": 2}
    # a torn write of step 3 must leave step 2 readable after crash
    dev.faults = FaultSchedule.torn_at("manifest", occurrence=1)
    with pytest.raises(InjectedCrash):
        jr.write({"step": 3})
    dev.faults = None
    dev.crash()
    assert jr.read() == {"step": 2}


@pytest.mark.parametrize("backend", BACKENDS)
def test_two_allocators_share_one_directory(backend, tmp_path):
    """Several live allocator handles over one device (manager + embedding
    mirror + recovery) must hand out disjoint regions, not stale-offset
    overlaps."""
    dev = mkpool(backend, tmp_path)
    a1 = PoolAllocator(dev)
    a2 = PoolAllocator(dev)
    r1 = a1.domain("d").alloc("x", shape=(64,), dtype="float32")
    r2 = a2.domain("d").alloc("y", shape=(64,), dtype="float32")
    r3 = a1.domain("d").alloc("z", shape=(64,), dtype="float32")
    offs = sorted([(r.off, r.off + r.nbytes) for r in (r1, r2, r3)])
    for (_s1, e1), (s2, _) in zip(offs, offs[1:], strict=False):
        assert e1 <= s2, f"overlapping regions: {offs}"
    assert a2.domain("d").get("z").off == r3.off    # visible via re-sync


# -- near-memory ops ---------------------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_nmp_ops_match_numpy(backend, tmp_path, rng):
    dev = mkpool(backend, tmp_path)
    a = PoolAllocator(dev)
    tab = rng.standard_normal((32, 8)).astype(np.float32)
    r = a.domain("emb").alloc("t", shape=tab.shape, dtype="float32")
    r.write_array(tab)
    q = NmpQueue(dev)
    idx = np.array([3, 31, 0, 3])
    np.testing.assert_array_equal(q.gather(r, idx), tab[idx])

    bags = rng.integers(0, 32, (5, 4))
    np.testing.assert_allclose(q.bag_gather(r, bags), tab[bags].sum(1),
                               rtol=1e-6)
    np.testing.assert_allclose(q.bag_gather(r, bags, combine="mean"),
                               tab[bags].mean(1), rtol=1e-6)

    old = q.undo_snapshot(r, np.array([1, 2]))
    np.testing.assert_array_equal(old, tab[[1, 2]])

    q.row_update(r, np.array([1, 2]), np.ones((2, 8), np.float32),
                 point="apply")
    dev.crash()                             # row_update persisted
    np.testing.assert_array_equal(r.read_array()[[1, 2]],
                                  np.ones((2, 8), np.float32))

    before = r.read_array().copy()
    q.scatter_add(r, np.array([0, 0, 5]), np.ones((3, 8), np.float32))
    exp = before.copy()
    np.add.at(exp, [0, 0, 5], np.ones((3, 8), np.float32))
    np.testing.assert_allclose(r.read_array(), exp, rtol=1e-6)


def test_nmp_accounting_link_vs_media(tmp_path, rng):
    """Bag lookups must move full rows inside the pool but only reduced
    vectors (plus indices) over the link — the paper's traffic claim."""
    dev = mkpool("dram", tmp_path)
    a = PoolAllocator(dev)
    tab = rng.standard_normal((1024, 32)).astype(np.float32)
    r = a.domain("emb").alloc("t", shape=tab.shape, dtype="float32")
    r.write_array(tab)
    dev.metrics.media.clear()
    dev.metrics.link.clear()
    q = NmpQueue(dev)
    bags = rng.integers(0, 1024, (64, 16))          # 16 rows reduced per bag
    out = q.bag_gather(r, bags)
    rows_bytes = bags.size * 32 * 4
    assert dev.metrics.media_bytes("bag_gather") == rows_bytes
    assert dev.metrics.link.get("link_out").nbytes == out.nbytes
    assert out.nbytes * 16 == rows_bytes            # 16x link saving
    assert dev.metrics.ndp_time_s > 0               # reduction ran on NDP


# -- fault schedules ---------------------------------------------------------

def test_fault_schedule_deterministic_occurrence(tmp_path):
    fs = FaultSchedule.crash_at("p", occurrence=3)
    assert fs.hit("p") == "ok" and fs.hit("p") == "ok"
    with pytest.raises(InjectedCrash):
        fs.hit("p")
    assert fs.hit("p") == "ok"              # fires exactly once

    fs2 = FaultSchedule.seeded(0, ("a", "b"))
    fs3 = FaultSchedule.seeded(0, ("a", "b"))
    assert [e.occurrence for e in fs2.events] == \
        [e.occurrence for e in fs3.events]


@pytest.mark.parametrize("backend", BACKENDS)
def test_dropped_flush_loses_data_silently(backend, tmp_path):
    dev = mkpool(backend, tmp_path,
                 faults=FaultSchedule.drop_at("apply", occurrence=1))
    a = PoolAllocator(dev)
    r = a.domain("d").alloc("x", shape=(4,), dtype="float32")
    r.write_array(np.ones(4, np.float32))
    r.persist(point="init")
    r.write_array(np.full(4, 9.0, np.float32))
    r.persist(point="apply")                # dropped: no error raised
    assert dev.metrics.dropped_flushes == 1
    dev.crash()
    np.testing.assert_array_equal(r.read_array(), np.ones(4, np.float32))


@pytest.mark.parametrize("backend", BACKENDS)
def test_torn_write_is_partial(backend, tmp_path):
    dev = mkpool(backend, tmp_path, faults=FaultSchedule.torn_at("apply"))
    a = PoolAllocator(dev)
    r = a.domain("d").alloc("x", shape=(1024,), dtype="float32")
    r.write_array(np.zeros(1024, np.float32))
    r.persist(point="init")
    r.write_array(np.full(1024, 3.0, np.float32))
    with pytest.raises(InjectedCrash):
        r.persist(point="apply")
    dev.crash()
    v = r.read_array()
    assert (v == 3.0).any() and (v == 0.0).any()
    assert dev.metrics.torn_writes == 1


# -- undo ring over a pool domain -------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_undo_ring_commit_crc_and_gc(backend, tmp_path, rng):
    dev = mkpool(backend, tmp_path)
    ring = UndoRing(PoolAllocator(dev), max_logs=3, compress=COMPRESS)
    for step in range(6):
        ring.append(step, np.arange(4) + step,
                    rng.standard_normal((4, 8)).astype(np.float32))
    assert ring.committed_steps() == [2, 3, 4, 5]   # ring capacity max_logs+1
    idx, rows, acc = ring.read(5)
    np.testing.assert_array_equal(idx, np.arange(4) + 5)
    assert acc is None
    ring.gc(keep_from=4)
    assert ring.committed_steps() == [4, 5]
    # committed entries survive crash; a torn payload invalidates the entry
    dev.crash()
    ring2 = UndoRing(PoolAllocator(dev), max_logs=3,
                     compress=COMPRESS)
    assert ring2.committed_steps() == [4, 5]


@pytest.mark.parametrize("compress", ["none", "zlib"])
def test_undo_ring_grows_slots(tmp_path, rng, compress):
    dev = mkpool("dram", tmp_path)
    ring = UndoRing(PoolAllocator(dev), max_logs=2, compress=compress)
    ring.append(0, np.arange(2), np.ones((2, 4), np.float32))
    big_idx = np.arange(512)
    ring.append(1, big_idx, np.ones((512, 4), np.float32))  # outgrows slot
    assert ring.committed_steps() == [0, 1]
    idx, rows, _ = ring.read(1)
    np.testing.assert_array_equal(idx, big_idx)


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("point,occurrence,phase", [
    # schedules are armed AFTER the two seed appends, so occurrences count
    # from the start of the growing append
    ("undo-grow-alloc", 1, "after"),   # new ring allocated, nothing carried
    ("undo-payload", 1, "before"),     # first carried entry: mid carry-over
    ("undo-payload", 2, "before"),     # second carried entry
    ("undo-meta", 1, "before"),        # carry done, meta flip not durable
    ("undo-meta", 1, "after"),         # flip durable, grow complete
])
def test_crash_mid_grow_loses_no_committed_entry(backend, point, occurrence,
                                                 phase, tmp_path, rng):
    """The _grow crash-safety contract: entries are copied into the new
    ring FIRST and meta flips LAST, so a power loss anywhere mid-grow
    recovers the old ring with every committed entry (and its COMMIT word)
    intact."""
    dev = mkpool(backend, tmp_path)
    ring = UndoRing(PoolAllocator(dev), max_logs=3, compress=COMPRESS)
    rows = {}
    for s in range(2):
        rows[s] = rng.standard_normal((4, 8)).astype(np.float32)
        ring.append(s, np.arange(4) + s, rows[s])
    dev.faults = FaultSchedule(
        events=(FaultEvent("crash", point, occurrence, phase),))
    with pytest.raises(InjectedCrash):      # entry outgrows slot -> grow
        ring.append(2, np.arange(512), np.ones((512, 8), np.float32))
    dev.faults = None
    dev.crash()                             # power loss mid-grow
    ring2 = UndoRing(PoolAllocator(dev), max_logs=3,
                     compress=COMPRESS)
    assert ring2.committed_steps() == [0, 1], \
        f"committed entries lost after crash at {point}"
    for s in range(2):
        idx, got, acc = ring2.read(s)
        np.testing.assert_array_equal(idx, np.arange(4) + s)
        np.testing.assert_allclose(got, rows[s], rtol=1e-6)


def test_regrow_after_crashed_grow_cannot_resurrect_stale_entries(tmp_path,
                                                                  rng):
    """A grow that crashed before its meta flip leaves a half-written
    ring<gen> in the directory. A later same-size grow reopens that region
    idempotently — its stale COMMIT words (for entries that may since have
    been GC'd) must be scrubbed, or recovery would roll the mirror back to
    ancient row images."""
    dev = mkpool("dram", tmp_path)
    ring = UndoRing(PoolAllocator(dev), max_logs=3, compress=COMPRESS)
    for s in range(2):
        ring.append(s, np.arange(4) + s, np.ones((4, 8), np.float32))
    big = (np.arange(512), np.ones((512, 8), np.float32))
    dev.faults = FaultSchedule.crash_at("undo-meta", occurrence=1)
    with pytest.raises(InjectedCrash):      # carry done, flip never durable
        ring.append(2, *big)
    dev.faults = None
    dev.crash()
    ring2 = UndoRing(PoolAllocator(dev), max_logs=3,
                     compress=COMPRESS)
    assert ring2.committed_steps() == [0, 1]
    ring2.gc(keep_from=2)                   # both tiers durable past 0, 1
    assert ring2.committed_steps() == []
    ring2.append(2, *big)                   # same need -> same ring1 region
    assert ring2.committed_steps() == [2], \
        "stale carried-over entries resurrected from the crashed grow"


@pytest.mark.parametrize("backend", BACKENDS)
def test_grow_reclaims_old_generation_ring(backend, tmp_path, rng):
    """Once the meta flip is durable, the outgrown generation's region is
    freed — the directory never accumulates dead rings across grows."""
    dev = mkpool(backend, tmp_path)
    a = PoolAllocator(dev)
    ring = UndoRing(a, max_logs=3, compress=COMPRESS)
    for s in range(2):
        ring.append(s, np.arange(4) + s, np.ones((4, 8), np.float32))
    ring.append(2, np.arange(512), np.ones((512, 8), np.float32))  # grows
    names = sorted(a.domain("undo-log").regions().keys())
    assert names == ["meta", f"ring{ring.gen}"], names
    assert ring.committed_steps() == [0, 1, 2]


@pytest.mark.parametrize("window", ["before-free", "during-free"])
def test_crash_window_around_grow_free_no_double_free(tmp_path, rng, window):
    """A crash between the meta flip and the old-ring free (or mid-free,
    tearing the directory write) leaks the old generation for one restart;
    the open-time sweep then reclaims it exactly once. Frees go by NAME,
    so the retry can never release the live ring or any region allocated
    since — the no-double-free argument."""
    dev = mkpool("dram", tmp_path)
    a = PoolAllocator(dev)
    ring = UndoRing(a, max_logs=3, compress=COMPRESS)
    rows = {}
    for s in range(2):
        rows[s] = rng.standard_normal((4, 8)).astype(np.float32)
        ring.append(s, np.arange(4) + s, rows[s])
    if window == "before-free":
        dev.faults = FaultSchedule.crash_at("undo-grow-free", occurrence=1)
    else:
        dev.faults = FaultSchedule.torn_at("undo-grow-free", occurrence=1)
    with pytest.raises(InjectedCrash):     # entry outgrows slot -> grow
        ring.append(2, np.arange(512), np.ones((512, 8), np.float32))
    dev.faults = None
    dev.crash()                            # power loss in the free window
    a2 = PoolAllocator(dev)
    ring2 = UndoRing(a2, max_logs=3, compress=COMPRESS)   # sweep reclaims
    dom = a2.domain("undo-log")
    assert sorted(dom.regions().keys()) == ["meta", f"ring{ring2.gen}"]
    assert ring2.committed_steps() == [0, 1]   # carried entries intact
    for s in range(2):
        idx, got, _ = ring2.read(s)
        np.testing.assert_array_equal(idx, np.arange(4) + s)
        np.testing.assert_allclose(got, rows[s], rtol=1e-6)
    # the already-reclaimed name is a directory miss, never a second release
    assert not dom.free_region("ring0")
    # the grown ring is live: the big entry now fits without another grow
    gen = ring2.gen
    ring2.append(2, np.arange(512), np.ones((512, 8), np.float32))
    assert ring2.gen == gen
    assert ring2.committed_steps() == [0, 1, 2]


def test_compress_none_leaves_engine_idle(tmp_path, rng):
    """With compression off the engine must charge nothing: no bytes, no
    busy time, no phantom DEFLATE energy, no sim calibration ratio."""
    dev = mkpool("dram", tmp_path)
    a = PoolAllocator(dev)
    tab = rng.standard_normal((32, 8)).astype(np.float32)
    mirror = a.domain("m").alloc("rows", shape=tab.shape, dtype="float32")
    mirror.write_array(tab)
    ring = UndoRing(a, max_logs=2, compress="none")
    ring.log_and_apply(0, mirror, np.arange(4), np.ones((4, 8), np.float32))
    q = NmpQueue(dev)
    r = a.domain("dense").alloc("slot0", shape=(8 << 10,), dtype="uint8")
    q.blob_put(r, b"\0" * 4096, compress="none")
    m = dev.metrics
    assert m.comp_raw_bytes == 0 and m.comp_stored_bytes == 0
    assert m.comp_time_s == 0.0 and m.energy()["comp"] == 0.0
    assert m.comp_ratio() == 1.0


def test_grow_carries_entries_and_flips_meta_last(tmp_path, rng):
    """A clean grow keeps everything; meta gen advances exactly once."""
    dev = mkpool("dram", tmp_path)
    ring = UndoRing(PoolAllocator(dev), max_logs=3, compress=COMPRESS)
    rows = {s: rng.standard_normal((4, 8)).astype(np.float32)
            for s in range(3)}
    for s in range(3):
        ring.append(s, np.arange(4) + s, rows[s])
    gen0 = ring.gen
    ring.append(3, np.arange(512), np.ones((512, 8), np.float32))
    assert ring.gen == gen0 + 1
    assert ring.committed_steps() == [0, 1, 2, 3]
    for s in range(3):
        _, got, _ = ring.read(s)
        np.testing.assert_allclose(got, rows[s], rtol=1e-6)


# -- undo codec / pool-side compression ---------------------------------------

@pytest.mark.parametrize("mode", ["none", "zlib"])
def test_undo_codec_lossless_roundtrip(rng, mode):
    idx = np.sort(rng.choice(10_000, 64, replace=False)).astype(np.int64)
    rows = rng.standard_normal((64, 16)).astype(np.float32)
    acc = rng.standard_normal((64, 16)).astype(np.float32)
    stored, flags, raw_len = uc.encode_payload(idx, rows, acc, mode)
    assert len(stored) <= raw_len
    i2, r2, a2 = uc.decode_payload(stored, 64, 16, flags)
    np.testing.assert_array_equal(i2, idx)
    np.testing.assert_array_equal(r2, rows)
    np.testing.assert_array_equal(a2, acc)


def test_undo_codec_zlib_shrinks_compressible_rows(rng):
    idx = np.arange(128, dtype=np.int64)
    rows = np.zeros((128, 32), np.float32)          # maximally compressible
    stored, flags, raw_len = uc.encode_payload(idx, rows, None, "zlib")
    assert uc.flags_mode(flags) == "zlib"
    assert len(stored) < raw_len // 4


def test_undo_codec_int8_is_relaxed_but_indices_exact(rng):
    idx = rng.choice(10_000, 32, replace=False).astype(np.int64)
    rows = rng.standard_normal((32, 64)).astype(np.float32)
    stored, flags, raw_len = uc.encode_payload(idx, rows, None, "int8")
    assert uc.flags_mode(flags) == "int8"
    assert len(stored) < raw_len // 2               # ~4x on the row part
    i2, r2, _ = uc.decode_payload(stored, 32, 64, flags)
    np.testing.assert_array_equal(i2, idx)          # indices stay lossless
    err = np.abs(r2 - rows)
    scale = np.abs(rows).max(axis=1, keepdims=True)
    assert (err <= scale / 127 + 1e-6).all()        # quantisation-bounded


def test_undo_ring_int8_mode_bounded_rollback(tmp_path, rng):
    dev = mkpool("dram", tmp_path)
    ring = UndoRing(PoolAllocator(dev), max_logs=2, compress="int8")
    rows = rng.standard_normal((16, 8)).astype(np.float32)
    ring.append(0, np.arange(16), rows)
    _, got, _ = ring.read(0)
    scale = np.abs(rows).max(axis=1, keepdims=True)
    assert (np.abs(got - rows) <= scale / 127 + 1e-6).all()
    # grow carries the STORED bytes verbatim: the one-shot quantisation
    # error must not compound through re-encode on carry-over
    ring.append(1, np.arange(512), np.ones((512, 8), np.float32))  # grows
    _, got2, _ = ring.read(0)
    np.testing.assert_array_equal(got2, got)


def test_blob_frame_roundtrip_and_crc(rng):
    raw = rng.standard_normal(4096).astype(np.float32).tobytes() + b"\0" * 8192
    framed = pc.frame(raw, "zlib")
    assert len(framed) < len(raw)                   # zeros compress
    assert pc.unframe(framed) == raw
    # CRC over the *stored* bytes: corrupt the compressed body
    bad = bytearray(framed)
    bad[-5] ^= 0xFF
    with pytest.raises(PoolError):
        pc.unframe(bytes(bad))
    # legacy (unframed) blobs pass through verbatim
    assert pc.unframe(raw) == raw


@pytest.mark.parametrize("backend", BACKENDS)
def test_blob_put_compresses_at_pool(backend, tmp_path, rng):
    dev = mkpool(backend, tmp_path)
    a = PoolAllocator(dev)
    raw = b"\0" * (32 << 10)
    r = a.domain("dense").alloc("slot0", shape=(pc.framed_len(len(raw)),),
                                dtype="uint8")
    q = NmpQueue(dev)
    stored = q.blob_put(r, raw, compress="zlib", point="dense-blob")
    assert stored < len(raw) // 4                   # hit media compressed
    dev.crash()                                     # ...and durable
    back = bytes(dev.read(r.off, stored, tag="dense"))
    assert pc.unframe(back) == raw
    m = dev.metrics
    assert m.comp_raw_bytes >= len(raw)
    assert m.comp_stored_bytes < m.comp_raw_bytes


# -- fused server-side undo capture ------------------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("compress", ["none", "zlib"])
def test_undo_log_append_fused(backend, compress, tmp_path, rng):
    """The tentpole op: capture + log + COMMIT + apply inside the pool, the
    logged image bit-identical to the pre-update rows, the apply durable."""
    dev = mkpool(backend, tmp_path)
    a = PoolAllocator(dev)
    tab = rng.standard_normal((128, 16)).astype(np.float32)
    mirror = a.domain("m").alloc("rows", shape=tab.shape, dtype="float32")
    mirror.write_array(tab)
    mirror.persist(point="load")
    ring = UndoRing(a, max_logs=4, compress=compress)
    idx = np.unique(rng.integers(0, 128, 32))
    new_rows = rng.standard_normal((idx.size, 16)).astype(np.float32)
    info = ring.log_and_apply(7, mirror, idx, new_rows)
    assert 0 < info["stored"] <= info["raw"]
    got_idx, got_rows, _ = ring.read(7)
    np.testing.assert_array_equal(got_idx, idx)
    np.testing.assert_array_equal(got_rows, tab[idx])   # pre-update image
    dev.crash()                                         # log + apply durable
    np.testing.assert_array_equal(
        mirror.read_array()[idx], new_rows)
    ring2 = UndoRing(PoolAllocator(dev), max_logs=4)
    assert ring2.committed_steps() == [7]


def test_free_region_releases_directory_and_quota(tmp_path):
    dev = mkpool("dram", tmp_path)
    a = PoolAllocator(dev)
    r1 = a.domain("d").alloc("x", shape=(64,), dtype="float32")
    # same-name realloc with a new shape: the allocator REPLACES the entry
    # (old bytes leaked, new offset) — verified here so callers know to
    # free-then-alloc explicitly
    r2 = a.domain("d").alloc("x", shape=(128,), dtype="float32")
    assert r2.off != r1.off and r2.nbytes == 512
    assert a.domain("d").regions().keys() == {"x"}
    assert a.domain("d").free_region("x")
    assert a.domain("d").get("x") is None
    assert not a.domain("d").free_region("x")       # idempotent miss


# -- embedding_ops pool strategy --------------------------------------------

def test_embedding_ops_pool_mode(tmp_path, rng):
    import jax
    import jax.numpy as jnp

    from repro.core import embedding_ops as eo

    tab = rng.standard_normal((64, 8)).astype(np.float32)
    dev = mkpool("dram", tmp_path)
    eo.attach_pool(EmbeddingPoolMirror(dev, tab))
    try:
        ids = np.array([[1, 5], [63, 0]])
        out = eo.lookup(jnp.asarray(tab), jnp.asarray(ids), mode="pool")
        np.testing.assert_allclose(np.asarray(out), tab[ids], rtol=1e-6)
        # works under jit via pure_callback
        outj = jax.jit(lambda t, i: eo.lookup(t, i, mode="pool"))(
            jnp.asarray(tab), jnp.asarray(ids))
        np.testing.assert_allclose(np.asarray(outj), tab[ids], rtol=1e-6)
        assert dev.metrics.link_bytes() > 0
    finally:
        eo.detach_pool()
    with pytest.raises(RuntimeError):
        eo.lookup(jnp.asarray(tab), jnp.asarray(ids), mode="pool")


def test_embedding_ops_pool_bag_and_update(tmp_path, rng):
    import jax.numpy as jnp

    from repro.core import embedding_ops as eo

    tabs = rng.standard_normal((4, 16, 8)).astype(np.float32)
    dev = mkpool("dram", tmp_path)
    mir = EmbeddingPoolMirror(dev, tabs)
    eo.attach_pool(mir)
    try:
        ids = rng.integers(0, 16, (3, 4, 5))
        bag = eo.bag_lookup(jnp.asarray(tabs), jnp.asarray(ids), mode="pool")
        flat = (ids + np.arange(4)[None, :, None] * 16).reshape(-1)
        ref = tabs.reshape(64, 8)[flat].reshape(3, 4, 5, 8).sum(2)
        np.testing.assert_allclose(np.asarray(bag), ref, rtol=1e-5)
        # near-memory update applies grads pool-side
        grad = np.ones((2, 8), np.float32)
        before = mir.region.read_array().reshape(64, 8)[[0, 1]].copy()
        mir.apply_grad(np.array([0, 1]), grad, lr=0.5)
        after = mir.region.read_array().reshape(64, 8)[[0, 1]]
        np.testing.assert_allclose(after, before - 0.5 * grad, rtol=1e-6)
    finally:
        eo.detach_pool()


# -- metrics / sim calibration ----------------------------------------------

def test_metrics_energy_and_snapshot(tmp_path, rng):
    dev = mkpool("pmem", tmp_path)
    a = PoolAllocator(dev)
    r = a.domain("d").alloc("x", shape=(256, 16), dtype="float32")
    r.write_array(rng.standard_normal((256, 16)).astype(np.float32))
    r.persist(point="p")
    q = NmpQueue(dev)
    q.bag_gather(r, rng.integers(0, 256, (8, 4)))
    snap = dev.metrics.snapshot()
    assert snap["device"] == "pmem"
    assert snap["energy_j"]["total"] > 0
    assert snap["media_bytes"] > snap["link_bytes"] > 0
    assert "bag_gather" in dev.metrics.report()


def test_engine_calibration_from_pool_counters(tmp_path, rng):
    from repro.sim import engine
    from repro.sim.models_rm import RMS

    dev = mkpool("pmem", tmp_path)
    a = PoolAllocator(dev)
    r = a.domain("d").alloc("x", shape=(4096, 32), dtype="float32")
    r.write_array(rng.standard_normal((4096, 32)).astype(np.float32))
    r.persist(point="p")
    NmpQueue(dev).gather(r, rng.integers(0, 4096, 2048))
    dev.metrics.record_comp(1000, 400)        # pool-side compression ran
    try:
        cal = engine.calibrate_from_pool(dev.metrics)
        assert cal["write_bps"] > 0 and cal["read_bps"] > 0
        assert cal["undo_comp_ratio"] == pytest.approx(0.4)
        res = engine.simulate("CXL-B", RMS["RM1"])
        assert res.batch_time > 0 and res.breakdown["Checkpoint"] >= 0
    finally:
        engine.clear_pool_calibration()
