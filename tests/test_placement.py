"""Epoch-versioned placement + live domain migration.

Covers the PlacementMap contract (epoch replay in order, torn tail record
falling back to the previous epoch — never a re-hash), the migration
crash-window matrix ({pre-copy, mid-copy, post-copy-pre-flip,
post-flip-pre-gc} x {sharded over pmem, sharded over remote}) with
bit-identical recovery, the domain wholly on exactly one shard, and the
open-time sweep reclaiming whatever the crash stranded (no double-free),
plus the capacity-watermark RebalancePolicy end to end through the
checkpoint manager (gauge trigger -> migration -> epoch in POOL.json ->
recovery on the final shard)."""
import json
import os

import jax
import numpy as np
import pytest

from repro.configs import get_arch
from repro.configs.base import CheckpointConfig, TrainConfig
from repro.core.checkpoint import recovery
from repro.core.checkpoint.manager import CheckpointManager
from repro.core.checkpoint.undo_log import UndoRing
from repro.data.synthetic import make_batches
from repro.pool import (DramPool, FaultSchedule, InjectedCrash, PlacementMap,
                        PmemPool, PoolAllocator, PoolError, PoolServer,
                        ShardedPool)
from repro.pool.sharded import MIGRATE_WINDOWS, SHARD_SPAN
from repro.training import train_loop

COMPRESS = os.environ.get("REPRO_POOL_COMPRESS", "zlib")
# the CI `rebalance` cell turns the watermark policy on for the whole
# sharded suite; tests here force it on regardless
REBALANCE = float(os.environ.get("REPRO_POOL_REBALANCE", "0") or 0)


# ---------------------------------------------------------------------------
# PlacementMap: epoch replay + torn-record fallback
# ---------------------------------------------------------------------------


def test_epochs_replay_in_order_and_newest_wins():
    pm = PlacementMap(shards=("a", "b", "c"))
    home = pm.place("embedding-mirror")
    pm1 = pm.with_epoch({"embedding-mirror": (home + 1) % 3,
                         "undo-log": (home + 1) % 3}, reason="mv1")
    pm2 = pm1.with_epoch({"embedding-mirror": (home + 2) % 3,
                          "undo-log": (home + 2) % 3}, reason="mv2")
    assert (pm.epoch, pm1.epoch, pm2.epoch) == (0, 1, 2)
    assert pm2.place("embedding-mirror") == (home + 2) % 3
    assert pm2.place("undo-log") == pm2.place("embedding-mirror")
    # untouched domains keep their hash placement across epochs
    assert pm2.place("manifest") == pm.place("manifest")
    # the json roundtrip preserves the full history
    back = PlacementMap.from_json(pm2.to_json())
    assert back == pm2
    assert back.place("embedding-mirror") == (home + 2) % 3


def test_group_follows_colocation_not_luck():
    """The alias-complete move/promote unit is placement policy: undo-log
    rides with embedding-mirror while co-located, and drops out the moment
    an explicit pin (or epoch move) separates them."""
    pm = PlacementMap(shards=("a", "b", "c"))
    assert pm.group("embedding-mirror") == ["embedding-mirror", "undo-log"]
    assert pm.group("undo-log") == ["undo-log"]       # followers lead nobody
    assert pm.group("manifest") == ["manifest"]
    split = pm.with_pin("undo-log",
                        (pm.place("embedding-mirror") + 1) % 3)
    assert split.group("embedding-mirror") == ["embedding-mirror"]
    moved = pm.with_epoch({"undo-log": (pm.place("embedding-mirror") + 1) % 3})
    assert moved.group("embedding-mirror") == ["embedding-mirror"]


def test_torn_epoch_record_falls_back_never_rehashes():
    pm = PlacementMap(shards=("a", "b", "c"))
    home = pm.place("embedding-mirror")
    moved1, moved2 = (home + 1) % 3, (home + 2) % 3
    pm2 = pm.with_epoch({"embedding-mirror": moved1, "undo-log": moved1}) \
            .with_epoch({"embedding-mirror": moved2, "undo-log": moved2})
    obj = pm2.to_json()
    # tear the NEWEST record: fall back to epoch 1 (moved1), not the hash
    obj["epochs"][-1]["crc"] ^= 0x1
    got = PlacementMap.from_json(obj)
    assert got.epoch == 1
    assert got.place("embedding-mirror") == moved1 != home
    # a malformed record ends the replay the same way
    obj2 = pm2.to_json()
    obj2["epochs"][-1] = {"garbage": True}
    assert PlacementMap.from_json(obj2).epoch == 1
    # an out-of-sequence record is not trusted either
    obj3 = pm2.to_json()
    obj3["epochs"] = [obj3["epochs"][1]]     # epoch 2 without epoch 1
    got3 = PlacementMap.from_json(obj3)
    assert got3.epoch == 0 and got3.place("embedding-mirror") == home


def test_recovery_lands_every_domain_on_its_final_shard(tmp_path):
    """A POOL.json containing multiple epochs: recovery replays them in
    order and every domain lands on its FINAL shard (both content and
    directory placement), without re-placing anything."""
    servers = _start_servers(tmp_path, 3)
    try:
        addrs = [s.addr for s in servers]
        root = str(tmp_path / "ck")
        cc = CheckpointConfig(directory=root, dense_interval=1,
                              pool_backend="sharded",
                              pool_shards=",".join(addrs),
                              pool_compress=COMPRESS)
        mgr, data, tc, b, init_fn = _train_manager(cc, steps=3)
        pool = mgr.pool
        home = pool.placement.place("embedding-mirror")
        hop1, hop2 = (home + 1) % 3, (home + 2) % 3
        for dst in (hop1, hop2):       # two epochs of movement
            info = pool.migrate_domain("embedding-mirror", dst,
                                       compress=COMPRESS)
            mgr.rebind_domains(info["moved"])
        # keep checkpointing after the moves: the rebound handles must
        # route tier-E to the new shard
        rng = np.random.default_rng(1)
        d = mgr.mirror_region.shape[-1]
        idx = np.unique(rng.integers(0, mgr.mirror_region.shape[0], 16)) \
            .astype(np.int64)
        rows = rng.standard_normal((idx.size, d)).astype(np.float32)
        mgr._do_tier_e(3, idx, rows)
        mirror_after = np.array(mgr.mirror_rows)
        mgr.pool.close()
        epochs = json.load(open(os.path.join(root, "POOL.json")))["epochs"]
        assert [e["epoch"] for e in epochs] == [1, 2]
        rec = recovery.recover(root)
        assert rec.pool.placement.epoch == 2
        assert rec.pool.placement.place("embedding-mirror") == hop2
        assert rec.pool.placement.place("undo-log") == hop2
        np.testing.assert_array_equal(rec.embed_rows, mirror_after)
        # the directory agrees with the placement: region offsets encode
        # the final shard's window, and no other shard holds a copy
        mirror = PoolAllocator(rec.pool).domain("embedding-mirror") \
            .get("rows")
        assert int(mirror.off) // SHARD_SPAN == hop2
        for i in range(3):
            if i != hop2:
                assert "embedding-mirror" not in rec.pool.shard_domains(i)
        rec.pool.close()
    finally:
        for s in servers:
            s.shutdown(close_device=True)


# ---------------------------------------------------------------------------
# the migration crash-window matrix
# ---------------------------------------------------------------------------


def _start_servers(tmp_path, n, tag=""):
    servers = []
    for i in range(n):
        dev = PmemPool(str(tmp_path / f"node{tag}{i}.img"), 1 << 21)
        servers.append(PoolServer(
            dev, f"unix:{tmp_path}/n{tag}{i}.sock").start())
    return servers


def _train_manager(cc, steps=3):
    b = get_arch("tinyllama-1.1b", smoke=True)
    tc = TrainConfig(embed_learning_rate=0.05, checkpoint=cc)
    data = make_batches(b.model, 4, 16, seed=3)
    init_fn, _, _, _ = train_loop.make_step_fns(b.model, tc)
    st0 = init_fn(jax.random.PRNGKey(tc.seed))
    mgr = CheckpointManager(b.model, cc, embed_init=st0["embed"])
    train_loop.train(b.model, tc, data, steps, relaxed=True, state=st0,
                     ckpt_manager=mgr)
    mgr.flush()
    return mgr, data, tc, b, init_fn


def _domain_bytes(pool, domain):
    """Every region's bytes for `domain`, read through placement routing."""
    out = {}
    for name, r in PoolAllocator(pool).domain(domain).regions().items():
        out[name] = bytes(pool.read(r.off, r.nbytes, tag="oracle"))
    return out


@pytest.mark.parametrize("flavor", ["pmem", "remote"])
@pytest.mark.parametrize("window", MIGRATE_WINDOWS)
def test_migration_crash_window_matrix(tmp_path, rng, window, flavor):
    """Crash at every named migration window, on sharded-over-pmem
    (in-process devices) and sharded-over-remote (memory-node servers):
    recovery is bit-identical, the domain group lives wholly on exactly one
    shard — the pre-flip source or the post-flip destination — and the
    open-time sweep reclaims the stranded copy (asserted, and re-sweeping
    frees nothing twice)."""
    paths = [str(tmp_path / f"m{i}.img") for i in range(2)]
    servers = []
    if flavor == "pmem":
        pool = ShardedPool([PmemPool(p, 1 << 20) for p in paths])
    else:
        servers = [PoolServer(PmemPool(p, 1 << 20),
                              f"unix:{tmp_path}/m{i}.sock").start()
                   for i, p in enumerate(paths)]
        pool = ShardedPool([s.addr for s in servers])
    sink_file = str(tmp_path / "placement.json")

    def sink(pm):
        with open(sink_file + ".tmp", "w") as f:
            json.dump(pm.to_json(), f)
        os.replace(sink_file + ".tmp", sink_file)

    pool.epoch_sink = sink
    sink(pool.placement)
    a = PoolAllocator(pool)
    tab = rng.standard_normal((96, 8)).astype(np.float32)
    mirror = a.domain("embedding-mirror").alloc("rows", shape=tab.shape,
                                                dtype="float32")
    mirror.write_array(tab)
    mirror.persist(point="mirror-load")
    ring = UndoRing(a, max_logs=4, compress=COMPRESS)
    idx = np.unique(rng.integers(0, 96, 20))
    new = rng.standard_normal((idx.size, 8)).astype(np.float32)
    ring.log_and_apply(0, mirror, idx, new)
    src = pool.placement.place("embedding-mirror")
    dst = 1 - src
    oracle = {d: _domain_bytes(pool, d)
              for d in ("embedding-mirror", "undo-log")}

    # mid-copy crashes on the SECOND window hit, so the first region has
    # already landed on the destination — the partial copy the sweep must
    # find; every other window fires on its first (only) hit
    occ = 2 if window == "migrate.mid-copy" else 1
    pool.faults = FaultSchedule.crash_at(window, occurrence=occ)
    with pytest.raises(InjectedCrash):
        pool.migrate_domain("embedding-mirror", dst, compress=COMPRESS)
    pool.close()                               # process death: cache gone

    # ---- restart: reopen nodes, replay the placement record, sweep ------
    if flavor == "remote":
        for i, s in enumerate(servers):
            s.shutdown(close_device=True)
            servers[i] = PoolServer(PmemPool.open(paths[i]),
                                    s.addr).start()
        shards2 = [s.addr for s in servers]
    else:
        shards2 = [PmemPool.open(p) for p in paths]
    pmap = PlacementMap.from_json(json.load(open(sink_file)))
    pool2 = ShardedPool(shards2, placement=pmap)
    swept = pool2.sweep_stale_domains()

    flipped = window == "migrate.post-flip-pre-gc"
    owner = dst if flipped else src
    stale = src if flipped else dst
    assert pool2.placement.place("embedding-mirror") == owner
    assert pool2.placement.place("undo-log") == owner
    assert pool2.placement.epoch == (1 if flipped else 0)
    # the stranded side was swept (pre-copy strands nothing on dst)
    if window != "migrate.pre-copy":
        assert any(s == stale for _, s in swept), \
            f"window {window}: nothing swept off shard {stale} ({swept})"
    assert "embedding-mirror" not in pool2.shard_domains(stale)
    assert "undo-log" not in pool2.shard_domains(stale)
    # sweeping again frees nothing (by-name frees can never double-free)
    assert pool2.sweep_stale_domains() == []

    # bit-identical content on the surviving side
    for dom, regions in oracle.items():
        got = _domain_bytes(pool2, dom)
        assert set(got) == set(regions), f"{dom}: region set changed"
        for name, blob in regions.items():
            assert got[name] == blob, f"{dom}/{name} not bit-identical"
    # and the ring still rolls back: committed entry readable, rows intact
    ring2 = UndoRing(PoolAllocator(pool2), 4, compress=COMPRESS)
    got_idx, got_rows, _ = ring2.read(0)
    np.testing.assert_array_equal(got_idx, idx)
    np.testing.assert_array_equal(got_rows, tab[idx])
    pool2.close()
    for s in servers:
        s.shutdown(close_device=True)


def test_migration_preserves_fused_append_link_bound(tmp_path, rng):
    """After a live migration the fused undo capture still runs wholly on
    the (new) owning shard: per-step trainer link bytes stay
    <= idx + new_rows + O(header)."""
    servers = _start_servers(tmp_path, 2, tag="lb")
    try:
        addrs = [s.addr for s in servers]
        cc = CheckpointConfig(directory=str(tmp_path / "ck"),
                              dense_interval=0, pool_backend="sharded",
                              pool_shards=",".join(addrs),
                              pool_compress=COMPRESS)
        b = get_arch("tinyllama-1.1b", smoke=True)
        tc = TrainConfig(checkpoint=cc)
        init_fn, _, _, _ = train_loop.make_step_fns(b.model, tc)
        st0 = init_fn(jax.random.PRNGKey(0))
        mgr = CheckpointManager(b.model, cc, embed_init=st0["embed"])
        d = mgr.mirror_region.shape[-1]
        nrows = mgr.mirror_region.shape[0]
        idx = np.unique(rng.integers(0, nrows, 32)).astype(np.int64)
        new = rng.standard_normal((idx.size, d)).astype(np.float32)
        mgr._do_tier_e(0, idx, new)                 # ring creation
        src = mgr.pool.placement.place("embedding-mirror")
        info = mgr.pool.migrate_domain("embedding-mirror", 1 - src,
                                       compress=COMPRESS)
        mgr.rebind_domains(info["moved"])
        assert int(mgr.mirror_region.off) // SHARD_SPAN == 1 - src
        mgr.pool.reset_metrics()
        sent = 0
        for step in (1, 2, 3):
            mgr._do_tier_e(step, idx, new)
            sent += idx.nbytes + new.nbytes
        m = mgr.pool.metrics
        assert m.link_bytes() <= sent + 3 * 4096, \
            f"fused capture left the owning shard after migration " \
            f"({m.link_bytes()}B link > {sent}B operands)"
        assert m.media_bytes("undo_snapshot") == 3 * idx.size * d * 4
        mgr.pool.close()
    finally:
        for s in servers:
            s.shutdown(close_device=True)


# ---------------------------------------------------------------------------
# capacity watermarks end to end
# ---------------------------------------------------------------------------


def test_watermark_policy_migrates_under_pressure(tmp_path):
    """3 shards, rebalancing on: overfill the mirror's shard past the high
    watermark with pinned ballast; the policy must migrate the mirror (its
    aliased undo-log in the SAME epoch — pinned ballast is never moved),
    training continues through the move, and a fresh recovery lands on the
    destination bit-identically."""
    servers = _start_servers(tmp_path, 3, tag="wm")
    try:
        addrs = [s.addr for s in servers]
        root = str(tmp_path / "ck")
        cc = CheckpointConfig(directory=root, dense_interval=0,
                              pool_backend="sharded",
                              pool_shards=",".join(addrs),
                              pool_compress=COMPRESS,
                              pool_rebalance=REBALANCE or 0.7)
        mgr, data, tc, b, init_fn = _train_manager(cc, steps=2)
        pool = mgr.pool
        assert pool.rebalance is not None
        pool.rebalance.check_every = 2
        hot = pool.placement.place("embedding-mirror")
        # pin ballast onto the hot shard and size it to cross the watermark
        pool.placement = pool.placement.with_pin("ballast", hot)
        mgr.record_placement()
        snap = pool.shard_metrics()[hot]
        need = int(pool.rebalance.high * snap["capacity_bytes"]
                   - snap["used_bytes"]) + (64 << 10)
        PoolAllocator(pool).domain("ballast").alloc(
            "fill", shape=(max(need, 1),), dtype="uint8")
        fill = pool.shard_metrics()[hot]
        assert fill["used_bytes"] / fill["capacity_bytes"] \
            >= pool.rebalance.high
        # train on: the writer thread polls the gauges and migrates
        st = init_fn(jax.random.PRNGKey(tc.seed))
        train_loop.train(b.model, tc, data, 6, relaxed=True, state=st,
                         ckpt_manager=mgr)
        mgr.flush()
        assert mgr.stats["migrations"] >= 1
        new_home = pool.placement.place("embedding-mirror")
        assert new_home != hot
        assert pool.placement.place("undo-log") == new_home
        # mirror and undo-log moved in the SAME epoch; ballast never moved
        last = pool.placement.epochs[-1]
        assert {"embedding-mirror", "undo-log"} <= set(last.moves)
        assert pool.placement.place("ballast") == hot
        mirror_after = np.array(mgr.mirror_rows)
        mgr.pool.close()
        rec = recovery.recover(root)
        assert rec.pool.placement.place("embedding-mirror") == new_home
        np.testing.assert_array_equal(rec.embed_rows, mirror_after)
        for i in range(3):
            if i != new_home:
                assert "embedding-mirror" not in rec.pool.shard_domains(i)
        rec.pool.close()
    finally:
        for s in servers:
            s.shutdown(close_device=True)


def test_reconnect_shard_after_node_restart(tmp_path, rng):
    """The operator path the drills script by hand: a node dies and
    restarts over its image; the fenced client is re-dialed in place and
    the shard serves the same bytes at the same offsets."""
    img = str(tmp_path / "rc0.img")
    servers = [PoolServer(PmemPool(img, 1 << 20),
                          f"unix:{tmp_path}/rc0.sock").start(),
               PoolServer(PmemPool(str(tmp_path / "rc1.img"), 1 << 20),
                          f"unix:{tmp_path}/rc1.sock").start()]
    try:
        pool = ShardedPool([s.addr for s in servers], pin={"d": 0})
        r = PoolAllocator(pool).domain("d").alloc("x", shape=(32,),
                                                  dtype="float32")
        v = rng.standard_normal(32).astype(np.float32)
        r.write_array(v)
        r.persist(point="p")
        servers[0].shutdown(close_device=True)      # node dies...
        with pytest.raises(PoolError):
            pool.read(r.off, r.nbytes)              # ...client is fenced
        servers[0] = PoolServer(PmemPool.open(img),
                                servers[0].addr).start()
        pool.reconnect_shard(0)
        got = np.frombuffer(bytes(pool.read(r.off, r.nbytes)), np.float32)
        np.testing.assert_array_equal(got, v)
        # only remote shards can re-dial
        local = ShardedPool([DramPool(1 << 18)])
        with pytest.raises(PoolError):
            local.reconnect_shard(0)
        pool.close()
        local.close()
    finally:
        for s in servers:
            s.shutdown(close_device=True)
