"""repro.serve: batched cached reads over the pool, commit-driven cache
coherence, read-replica failover, readonly tenant isolation, and the
checkpoint manager's replica refresh.

Backend-parametrized tests honor REPRO_POOL_BACKENDS like test_pool.py."""
import os
import types

import numpy as np
import pytest

from repro.core.checkpoint.undo_log import UndoRing, open_ring
from repro.pool import (DramPool, NmpQueue, PmemPool, PoolAllocator,
                        PoolServer, RemotePool, ShardedPool,
                        TenantIsolationError, replica_domain)
from repro.serve import (CommitTailer, EmbeddingServeTier, HotRowCache,
                         ReplicaReader, RequestBatcher, make_commit_hook)

BACKENDS = [b.strip() for b in os.environ.get(
    "REPRO_POOL_BACKENDS", "dram,pmem").split(",") if b.strip()]

_SOCK_SEQ = [0]


def mkpool(backend, tmp_path, capacity=1 << 18):
    if backend == "dram":
        return DramPool(capacity)
    if backend == "pmem":
        return PmemPool(str(tmp_path / "pool.img"), capacity)
    if backend == "remote":
        _SOCK_SEQ[0] += 1
        srv = PoolServer(DramPool(capacity),
                         f"unix:{tmp_path}/s{_SOCK_SEQ[0]}.sock").start()
        dev = RemotePool(srv.addr)
        dev._test_server = srv
        return dev
    if backend == "sharded":
        _SOCK_SEQ[0] += 1
        seq = _SOCK_SEQ[0]
        srvs = [PoolServer(DramPool(capacity),
                           f"unix:{tmp_path}/s{seq}n{i}.sock").start()
                for i in range(2)]
        dev = ShardedPool([s.addr for s in srvs])
        dev._test_servers = srvs
        return dev
    raise ValueError(f"unknown backend {backend!r}")


def seed_mirror(pool, V=64, d=8):
    rows = np.arange(V * d, dtype=np.float32).reshape(V, d)
    reg = PoolAllocator(pool).domain("embedding-mirror").alloc(
        "rows", shape=(V, d), dtype="float32")
    reg.write_array(rows)
    reg.persist(point="mirror-load")
    return reg, rows


# -- cache / batcher units ----------------------------------------------------

def test_hot_row_cache_lru_and_counters():
    from repro.pool import PoolMetrics
    m = PoolMetrics(device_name="serve")
    c = HotRowCache(2, metrics=m)
    c.put_many([1, 2], np.ones((2, 4), np.float32))
    hits, missing = c.get_many([1, 2, 3])
    assert set(hits) == {1, 2} and missing == [3]
    c.put_many([3], np.ones((1, 4)))       # get_many MRU'd 1 then 2 -> 1 LRU
    assert len(c) == 2
    hits, missing = c.get_many([1])
    assert missing == [1]                  # 1 was the LRU, evicted
    assert m.cache_hits == 2 and m.cache_misses == 2
    assert c.invalidate([2, 99]) == 1      # only 2 was cached
    assert m.cache_invalidations == 1


def test_batcher_dedup_one_gather():
    calls = []

    def gather(idx):
        calls.append(np.array(idx))
        return np.asarray(idx, np.float32)[:, None] * np.ones(4, np.float32)

    b = RequestBatcher(gather, HotRowCache(64))
    out = b.lookup_batch([np.array([5, 3, 5]), np.array([[3, 7], [7, 5]])])
    assert len(calls) == 1                       # ONE gather for the batch
    assert sorted(calls[0].tolist()) == [3, 5, 7]  # deduplicated
    np.testing.assert_allclose(out[0][:, 0], [5, 3, 5])
    assert out[1].shape == (2, 2, 4)
    np.testing.assert_allclose(out[1][..., 0], [[3, 7], [7, 5]])
    # second batch over the same ids: served fully from cache
    b.lookup_batch([np.array([3, 5, 7])])
    assert len(calls) == 1


def test_batcher_view_path_bit_identical_to_copying_reference():
    """Zero-copy parity: the slice-once batcher (cache hits as views, one
    fancy-index per batch) returns byte-for-byte what a naive per-row
    copying implementation returns, across mixed hot/cold batches."""
    rng = np.random.default_rng(7)
    table = rng.standard_normal((64, 8)).astype(np.float32)

    def gather(idx):
        return table[np.asarray(idx, np.int64)]

    def reference(cache_rows, requests):
        out = []
        for r in requests:
            r = np.asarray(r, np.int64)
            d = table.shape[-1]
            if r.size == 0:
                out.append(np.empty(r.shape + (d,), table.dtype))
                continue
            rows = np.stack([np.array(table[i], copy=True)
                             for i in r.reshape(-1)])
            out.append(rows.reshape(r.shape + (d,)))
        return out

    b = RequestBatcher(gather, HotRowCache(32))
    batches = [
        [np.array([1, 2, 3])],                      # all cold
        [np.array([1, 2]), np.array([2, 3])],       # all hot
        [np.array([[1, 9], [2, 40]]), np.array([9, 1, 63])],  # mixed
        [np.array([], dtype=np.int64), np.array([5])],        # empty req
    ]
    for reqs in batches:
        got = b.lookup_batch(reqs)
        want = reference(None, reqs)
        assert len(got) == len(want)
        for g, w in zip(got, want):
            assert g.shape == w.shape
            assert g.dtype == w.dtype
            assert g.tobytes() == w.tobytes()       # bit-identical
    # the cache really holds views, not per-row copies: every cached row
    # aliases a shared batch block
    hits, _ = b.cache.get_many([1, 2])
    assert all(h.base is not None for h in hits.values())
    assert not any(h.flags.writeable for h in hits.values())


# -- serve-after-commit coherence (all backends) ------------------------------

@pytest.mark.parametrize("backend", BACKENDS)
def test_serve_sees_committed_rows_exact_invalidation(backend, tmp_path):
    pool = mkpool(backend, tmp_path)
    reg, rows = seed_mirror(pool)
    ring = UndoRing(PoolAllocator(pool), max_logs=8)
    tier = EmbeddingServeTier(pool, cache_rows=32)

    out = tier.serve_batch([np.array([1, 2, 3]), np.array([2, 3, 4])])
    np.testing.assert_array_equal(out[0], rows[[1, 2, 3]])
    assert tier.metrics.cache_misses == 4        # unique ids, one gather

    # trainer commits step 0 touching rows {2, 9}: 2 is cached, 9 is not
    new = np.full((2, 8), 42.0, np.float32)
    ring.log_and_apply(0, reg, np.array([2, 9]), new)
    info = tier.poll_coherence()
    assert info["steps"] == 1 and info["watermark"] == 0
    assert info["evicted"] == 1                  # exactly the cached row
    assert tier.metrics.cache_invalidations == 1

    out = tier.serve_batch([np.array([2, 9, 1])])
    np.testing.assert_array_equal(out[0][0], new[0])   # fresh row 2
    np.testing.assert_array_equal(out[0][1], new[1])   # fresh row 9
    np.testing.assert_array_equal(out[0][2], rows[1])  # untouched row
    pool.close()


@pytest.mark.parametrize("backend", BACKENDS)
def test_tailer_follows_ring_growth(backend, tmp_path):
    pool = mkpool(backend, tmp_path)
    reg, rows = seed_mirror(pool)
    ring = UndoRing(PoolAllocator(pool), max_logs=4)
    tier = EmbeddingServeTier(pool, cache_rows=32)
    tier.serve_batch([np.arange(8)])
    ring.log_and_apply(0, reg, np.array([1]),
                       np.zeros((1, 8), np.float32))
    assert tier.poll_coherence()["steps"] == 1
    # a much bigger entry forces a ring grow (generation flip)
    big = np.arange(48)
    ring.log_and_apply(1, reg, big,
                       np.zeros((big.size, 8), np.float32))
    info = tier.poll_coherence()
    assert info["watermark"] == 1                # tailer rebound to new gen
    pool.close()


def test_commit_hook_invalidates_inline():
    cache = HotRowCache(8)
    cache.put_many([1, 2, 3], np.ones((3, 4), np.float32))
    tailer = types.SimpleNamespace(watermark=-1)
    hook = make_commit_hook(cache, tailer)
    hook(5, np.array([2, 7]))
    assert len(cache) == 2 and tailer.watermark == 5
    hits, missing = cache.get_many([2])
    assert missing == [2]


# -- readonly tenant isolation ------------------------------------------------

def test_readonly_tenant_denied_mutations(tmp_path):
    srv = PoolServer(DramPool(1 << 18), f"unix:{tmp_path}/ro.sock").start()
    rw = RemotePool(srv.addr)
    reg, rows = seed_mirror(rw)
    ring = UndoRing(PoolAllocator(rw), max_logs=4)
    ring.log_and_apply(0, reg, np.array([1]), np.ones((1, 8), np.float32))

    ro = RemotePool(srv.addr, readonly=True)
    alloc = PoolAllocator(ro)
    # reads + idempotent reopen work
    r = alloc.domain("embedding-mirror").get("rows")
    assert r is not None
    q = NmpQueue(ro)
    np.testing.assert_array_equal(q.gather(r, np.array([3])),
                                  rw.read(reg.off + 3 * 32, 32)
                                  .view(np.float32).reshape(1, 8))
    reopened = alloc.domain("embedding-mirror").alloc(
        "rows", shape=r.shape, dtype="float32")
    assert reopened.off == r.off
    # every mutating op is denied with the typed error
    with pytest.raises(TenantIsolationError):
        ro.write(r.off, b"\x00" * 8)
    with pytest.raises(TenantIsolationError):
        q.row_update(r, np.array([0]), np.zeros((1, 8), np.float32))
    with pytest.raises(TenantIsolationError):
        q.scatter_add(r, np.array([0]), np.zeros((1, 8), np.float32))
    with pytest.raises(TenantIsolationError):
        alloc.domain("embedding-mirror").alloc("new", shape=(4,),
                                               dtype="float32")
    with pytest.raises(TenantIsolationError):
        alloc.domain("embedding-mirror").free_region("rows")
    ro_ring = open_ring(ro, max_logs=4, readonly=True)
    with pytest.raises(TenantIsolationError):
        ro_ring.log_and_apply(1, r, np.array([0]),
                              np.zeros((1, 8), np.float32))
    # the readonly opener still tails commits
    tailer = CommitTailer(ro_ring, HotRowCache(4))
    assert tailer.poll()["watermark"] == 0
    # ...and the read-write tenant is unaffected
    ring.log_and_apply(1, reg, np.array([2]), np.ones((1, 8), np.float32))
    srv.shutdown(close_device=True)


def test_readonly_local_allocator_guards():
    pool = DramPool(1 << 18)
    PoolAllocator(pool).domain("d").alloc("x", shape=(4,), dtype="float32")
    ro = PoolAllocator(pool, readonly=True)
    assert ro.domain("d").get("x") is not None
    with pytest.raises(TenantIsolationError):
        ro.domain("d").alloc("y", shape=(4,), dtype="float32")
    with pytest.raises(TenantIsolationError):
        ro.domain("d").free_region("x")
    with pytest.raises(TenantIsolationError):
        ro.free_domain("d")


# -- read replica (sharded) ---------------------------------------------------

def _sharded_with_replica(tmp_path, V=64, d=8):
    pool = mkpool("sharded", tmp_path)
    reg, rows = seed_mirror(pool, V, d)
    ring = UndoRing(PoolAllocator(pool), max_logs=8)
    ring.log_and_apply(0, reg, np.array([5]),
                       np.full((1, d), 5.5, np.float32))
    rows = np.array(rows)
    rows[5] = 5.5
    primary = pool.placement.place("embedding-mirror")
    dst = 1 - primary
    pool.replicate_domain("embedding-mirror", dst, watermark=0)
    return pool, reg, rows, ring, primary, dst


def test_replica_survives_primary_kill(tmp_path):
    pool, reg, rows, ring, primary, dst = _sharded_with_replica(tmp_path)
    tier = EmbeddingServeTier(pool, cache_rows=16, replica=True)
    assert tier.replica.watermark() == 0
    out = tier.serve_batch([np.array([5, 1])])
    np.testing.assert_array_equal(out[0], rows[[5, 1]])

    pool._test_servers[primary].shutdown()       # kill -9 the primary node
    tier.cache.clear()
    out = tier.serve_batch([np.array([5, 2])])   # replica serves, same data
    np.testing.assert_array_equal(out[0], rows[[5, 2]])
    assert tier.failovers >= 1
    assert tier.staleness_bound() <= 1           # the declared lag bound
    b = tier.bag_lookup(np.array([[1, 2]]))
    np.testing.assert_allclose(b[0], rows[1] + rows[2])
    pool._test_servers[dst].shutdown(close_device=True)


def test_replica_pin_survives_sweep(tmp_path):
    pool, reg, rows, ring, primary, dst = _sharded_with_replica(tmp_path)
    assert pool.placement.explicit(replica_domain("embedding-mirror")) == dst
    assert pool.sweep_stale_domains() == []      # pin protects the replica
    rr = ReplicaReader(pool)
    np.testing.assert_array_equal(rr.gather([5])[0], rows[5])
    pool.close()


def test_replica_refresh_advances_watermark(tmp_path):
    pool, reg, rows, ring, primary, dst = _sharded_with_replica(tmp_path)
    ring.log_and_apply(1, reg, np.array([7]),
                       np.full((1, 8), 7.7, np.float32))
    info = pool.replicate_domain("embedding-mirror", dst, watermark=1)
    assert info["regions"] >= 1 and info["link_bytes"] > 0
    rr = ReplicaReader(pool)
    assert rr.watermark() == 1
    np.testing.assert_allclose(rr.gather([7])[0], 7.7)
    pool.close()


def test_replica_reader_rebinds_across_reallocating_refresh():
    """A refresh that RE-ALLOCATED the replica regions (the source grew, so
    free+alloc moved the copy) must not leave a long-lived reader serving
    from the freed extent. Every shard runs under CheckedPool — exactly what
    REPRO_POOL_CHECK=1 wraps — so a stale handle would trip use-after-free
    instead of silently returning garbage; the reader re-resolves when the
    directory entry changed and keeps serving coherent rows."""
    from repro.analysis.checker import CheckedPool

    pool = ShardedPool([CheckedPool(DramPool(1 << 20)),
                        CheckedPool(DramPool(1 << 20))],
                       pin={"embedding-mirror": 0})
    dom = PoolAllocator(pool).domain("embedding-mirror")
    rows = np.arange(64 * 8, dtype=np.float32).reshape(64, 8)
    reg = dom.alloc("rows", shape=(64, 8), dtype="float32")
    reg.write_array(rows)
    reg.persist(point="mirror-load")
    pool.replicate_domain("embedding-mirror", 1, watermark=0)
    reader = ReplicaReader(pool)
    np.testing.assert_array_equal(reader.gather([3, 9]), rows[[3, 9]])
    assert reader.watermark() == 0
    # vocab growth: the source region is retired and re-allocated bigger,
    # and the next refresh free+reallocs the replica copy at a new offset
    dom.free_region("rows")
    rows2 = np.arange(96 * 8, dtype=np.float32).reshape(96, 8) + 1000.0
    reg2 = dom.alloc("rows", shape=(96, 8), dtype="float32")
    reg2.write_array(rows2)
    reg2.persist(point="mirror-load")
    pool.replicate_domain("embedding-mirror", 1, watermark=1)
    # the reader's cached handles predate the realloc: rebind, don't serve
    # stale bytes (or row 3 would still read as the pre-growth value)
    np.testing.assert_array_equal(reader.gather([3, 80]), rows2[[3, 80]])
    assert reader.watermark() == 1
    np.testing.assert_array_equal(reader.bag_gather([[1, 2]])[0],
                                  rows2[1] + rows2[2])
    pool.close()


def test_manager_replicates_on_commit(tmp_path):
    from repro.configs.base import CheckpointConfig
    from repro.core.checkpoint.manager import CheckpointManager

    pool = mkpool("sharded", tmp_path)
    dst = 1 - pool.placement.place("embedding-mirror")
    ccfg = CheckpointConfig(directory=str(tmp_path / "ckpt"),
                            pool_backend="sharded", max_undo_logs=8,
                            pool_replica=dst, pool_replica_every=1)
    cfg = types.SimpleNamespace(arch_type="transformer")
    table = np.arange(32 * 4, dtype=np.float32).reshape(32, 4)
    mgr = CheckpointManager(cfg, ccfg, pool=pool,
                            embed_init={"table": table})
    seen = []
    mgr.add_commit_hook(lambda step, idx: seen.append((step, list(idx))))
    mgr._do_tier_e(0, np.array([3]), np.full((1, 4), 9.0, np.float32))
    assert seen == [(0, [3])]
    assert mgr.stats["replica_refreshes"] == 1
    assert mgr.stats["replica_link_bytes"] > 0
    rr = ReplicaReader(pool)
    assert rr.watermark() == 0
    np.testing.assert_allclose(rr.gather([3])[0], 9.0)
    mgr.close()
