"""Disaggregated-pool ops: near-data lookup/bag vs plain gather, strategy
auto-pick, gradient (near-data update) equivalence under shard_map."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the optional dev dep
from hypothesis import given, settings, strategies as st

from repro.core import embedding_ops as eo
from repro.distributed import sharding
from repro.launch.mesh import make_local_mesh


def test_lookup_no_context_is_take(rng):
    t = jnp.asarray(rng.standard_normal((64, 8)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 64, (3, 5)).astype(np.int32))
    np.testing.assert_array_equal(np.asarray(eo.lookup(t, ids)),
                                  np.asarray(jnp.take(t, ids, axis=0)))


@pytest.mark.parametrize("mode", ["near_data", "table_gather", "auto"])
def test_lookup_modes_match_on_mesh(rng, mode):
    mesh = make_local_mesh(model_parallel=1)
    t = jnp.asarray(rng.standard_normal((64, 8)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 64, (4, 5)).astype(np.int32))
    with sharding.use_sharding(mesh, {"batch": "data"}):
        with eo.lookup_mode(mode):
            got = jax.jit(lambda t, i: eo.lookup(t, i))(t, ids)
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(jnp.take(t, ids, axis=0)),
                               atol=1e-6)


@pytest.mark.parametrize("mode", ["near_data", "table_gather"])
def test_bag_modes_match_on_mesh(rng, mode):
    mesh = make_local_mesh(model_parallel=1)
    T, R, d = 3, 32, 8
    tables = jnp.asarray(rng.standard_normal((T, R, d)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, R, (4, T, 6)).astype(np.int32))
    want = eo.bag_lookup(tables, ids)          # no-context reference
    with sharding.use_sharding(mesh, {"batch": "data"}):
        with eo.lookup_mode(mode):
            got = jax.jit(lambda t, i: eo.bag_lookup(t, i))(tables, ids)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_near_data_gradient_is_scatter_add(rng):
    """The VJP of the shard_map near-data lookup == scatter-add (the
    near-data update of the paper)."""
    mesh = make_local_mesh(model_parallel=1)
    t = jnp.asarray(rng.standard_normal((32, 4)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 32, (8,)).astype(np.int32))
    ct = jnp.asarray(rng.standard_normal((8, 4)).astype(np.float32))

    def f_plain(t):
        return (jnp.take(t, ids, axis=0) * ct).sum()

    with sharding.use_sharding(mesh, {"batch": None}):
        with eo.lookup_mode("near_data"):
            def f_pool(t):
                return (eo.lookup(t, ids) * ct).sum()
            g_pool = jax.grad(f_pool)(t)
    g_plain = jax.grad(f_plain)(t)
    np.testing.assert_allclose(np.asarray(g_pool), np.asarray(g_plain),
                               atol=1e-6)


def test_auto_strategy_picks_by_traffic():
    # decode-ish: few tokens, big vocab -> near_data
    assert eo._pick("auto", tokens=128, vocab=150000, tp=16) == "near_data"
    # training: 1M tokens, small vocab -> table_gather
    assert eo._pick("auto", tokens=1_000_000, vocab=32000, tp=16) \
        == "table_gather"
    assert eo._pick("auto", 10, 100, 1) == "table_gather"


@settings(deadline=None, max_examples=10)
@given(seed=st.integers(0, 99), b=st.integers(1, 6), k=st.integers(1, 8))
def test_property_bag_sum(seed, b, k):
    rng = np.random.default_rng(seed)
    tables = jnp.asarray(rng.standard_normal((2, 16, 4)).astype(np.float32))
    ids = jnp.asarray(rng.integers(0, 16, (b, 2, k)).astype(np.int32))
    got = eo.bag_lookup(tables, ids)
    want = np.zeros((b, 2, 4), np.float32)
    for bi in range(b):
        for t in range(2):
            for li in range(k):
                want[bi, t] += np.asarray(tables)[t, int(ids[bi, t, li])]
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)
