"""Wire protocol v2: version negotiation (mixed-version matrix), the typed
op registry as the ONE op table, pipelined request/response correlation
(fence-on-desync retired), scatter-gather batch frames, torn-frame isolation
mid-pipeline, keepalives on quiet connections, and per-op timeout classes."""
import socket
import struct
import threading
import time

import numpy as np
import pytest

from repro.pool import (DramPool, PmemPool, PoolAllocator,
                        PoolConnectionError, PoolError, PoolServer,
                        PoolTimeoutError, RemotePool, Timeouts, make_pool)
from repro.pool import protocol, remote, server, sharded
from repro.pool.protocol import (WIRE_V1, WIRE_V2, PoolChannel, recv_frame,
                                 send_frame, wire_from_env)


@pytest.fixture
def srv(tmp_path):
    s = PoolServer(DramPool(1 << 18), f"unix:{tmp_path}/pool.sock").start()
    yield s
    s.shutdown(close_device=True)


def _mkdata(dev, n=64, name="x", domain="d"):
    r = PoolAllocator(dev).domain(domain).alloc(name, shape=(n,),
                                                dtype="uint8")
    dev.write(r.off, np.arange(n, dtype=np.uint8))
    return r


# -- one op table -------------------------------------------------------------

def test_single_op_table():
    """Acceptance: remote.py, server.py, and sharded.py all dispatch off
    THE registry objects in protocol.py — no drifting copies."""
    assert remote.OPS is protocol.OPS
    assert remote.NMP_OPS is protocol.NMP_OPS
    assert server.OPS is protocol.OPS
    assert server.NMP_OPS is protocol.NMP_OPS
    assert sharded.NMP_OPS is protocol.NMP_OPS


def test_registry_covers_server_dispatch():
    """Every wire op the server dispatches has a registry descriptor (and
    nothing in the registry is undispatchable)."""
    for op, spec in protocol.OPS.items():
        assert spec.name == op
    for kind, spec in protocol.NMP_OPS.items():
        assert spec.kind == kind
        assert callable(spec.run)


# -- version negotiation ------------------------------------------------------

def test_v2_client_against_v1_server(tmp_path):
    s = PoolServer(DramPool(1 << 18), f"unix:{tmp_path}/v1.sock",
                   wire=WIRE_V1).start()
    try:
        dev = RemotePool(s.addr, timeout=20.0)     # asks for v2
        assert dev.wire == WIRE_V1
        r = _mkdata(dev)
        assert bytes(dev.read(r.off, 8)) == bytes(range(8))
        # the async surface degrades to completed depth-1 futures
        fut = dev.read_async(r.off, 8)
        assert bytes(fut.result()) == bytes(range(8))
        assert dev.read_batch([(r.off, 4), (r.off + 4, 4)]) == \
            [bytes(range(4)), bytes(range(4, 8))]
        dev.close()
    finally:
        s.shutdown(close_device=True)


def test_v1_client_against_v2_server(srv):
    dev = RemotePool(srv.addr, timeout=20.0, wire=WIRE_V1)
    assert dev.wire == WIRE_V1
    r = _mkdata(dev)
    assert bytes(dev.read(r.off, 8)) == bytes(range(8))
    dev.close()


def test_v2_both_sides_negotiates_v2(srv):
    dev = RemotePool(srv.addr, timeout=20.0)
    assert dev.wire == WIRE_V2
    assert dev.wire_stats()["wire"] == WIRE_V2
    dev.close()


def test_wire_from_env(monkeypatch):
    monkeypatch.setenv("REPRO_POOL_WIRE", "v1")
    assert wire_from_env() == WIRE_V1
    monkeypatch.setenv("REPRO_POOL_WIRE", "2")
    assert wire_from_env() == WIRE_V2
    monkeypatch.delenv("REPRO_POOL_WIRE")
    assert wire_from_env() == WIRE_V2


# -- pipelining ---------------------------------------------------------------

def _pools(tmp_path, servers):
    """The four backends behind one factory: (name, device) pairs."""
    out = [("dram", DramPool(1 << 18)),
           ("pmem", PmemPool(str(tmp_path / "p.img"), 1 << 18))]
    s1 = PoolServer(DramPool(1 << 18), f"unix:{tmp_path}/r.sock").start()
    servers.append(s1)
    out.append(("remote", RemotePool(s1.addr, timeout=20.0)))
    s2 = PoolServer(DramPool(1 << 18), f"unix:{tmp_path}/s0.sock").start()
    s3 = PoolServer(DramPool(1 << 18), f"unix:{tmp_path}/s1.sock").start()
    servers.extend([s2, s3])
    out.append(("sharded", make_pool("sharded",
                                     shards=f"{s2.addr},{s3.addr}",
                                     timeout=20.0)))
    return out


def test_pipeline_depth8_parity_all_backends(tmp_path):
    """Depth-8 pipelined reads return byte-identical results to
    sequential reads on every backend."""
    servers = []
    try:
        for name, dev in _pools(tmp_path, servers):
            r = _mkdata(dev, n=256)
            seq = [bytes(dev.read(r.off + 8 * i, 8)) for i in range(8)]
            futs = [dev.read_async(r.off + 8 * i, 8) for i in range(8)]
            piped = [bytes(f.result()) for f in futs]
            assert piped == seq, name
            batched = dev.read_batch([(r.off + 8 * i, 8)
                                      for i in range(8)])
            assert [bytes(b) for b in batched] == seq, name
            dev.close()
    finally:
        for s in servers:
            s.shutdown(close_device=True)


def test_pipelined_error_rejects_only_its_future(srv):
    """Fence-on-desync is retired: a failed pipelined op rejects ITS
    future; requests before and after it complete, and the connection
    keeps serving."""
    dev = RemotePool(srv.addr, timeout=20.0)
    assert dev.wire == WIRE_V2
    r = _mkdata(dev)
    good1 = dev.read_async(r.off, 8)
    bad = dev.read_async(1 << 29, 8)        # beyond capacity: typed error
    good2 = dev.read_async(r.off + 8, 8)
    assert bytes(good1.result()) == bytes(range(8))
    with pytest.raises(PoolError):
        bad.result()
    assert bytes(good2.result()) == bytes(range(8, 16))
    assert not dev.closed                   # the connection survived
    assert bytes(dev.read(r.off, 4)) == bytes(range(4))
    dev.close()


def test_batch_frame_is_one_round_trip(srv):
    dev = RemotePool(srv.addr, timeout=20.0)
    r = _mkdata(dev, n=128)
    calls = []
    orig = dev._request

    def counting(hdr, body=b""):
        calls.append(hdr["op"])
        return orig(hdr, body)

    dev._request = counting
    try:
        got = dev.read_batch([(r.off + i, 1) for i in range(16)])
    finally:
        dev._request = orig
    assert calls == ["batch"]
    assert b"".join(bytes(b) for b in got) == bytes(range(16))
    dev.close()


# -- torn frames --------------------------------------------------------------

def _raw_hello(sock, wire=WIRE_V2):
    send_frame(sock, {"op": "hello", "tenant": "torn", "quota": 0,
                      "wire": wire})
    hdr, _ = recv_frame(sock)
    assert hdr.get("ok"), hdr
    return int(hdr.get("wire", WIRE_V1))


def test_torn_frame_mid_pipeline_rejects_exactly_one(srv):
    """A frame whose header fails to parse (stream still at a frame
    boundary) produces ONE error reply; requests around it succeed on the
    same connection."""
    kind, target = protocol.parse_addr(srv.addr)
    sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    sock.connect(target)
    sock.settimeout(10.0)
    try:
        assert _raw_hello(sock) == WIRE_V2
        send_frame(sock, {"op": "capacity", "rid": 1})
        garbage = b"\x00not json at all\xff"
        sock.sendall(struct.pack("<I", 4 + len(garbage))
                     + struct.pack("<I", len(garbage)) + garbage)
        send_frame(sock, {"op": "capacity", "rid": 3})
        replies = [recv_frame(sock)[0] for _ in range(3)]
        by_rid = {h.get("rid"): h for h in replies}
        assert by_rid[1]["ok"] and by_rid[3]["ok"]
        (err,) = [h for h in replies if not h.get("ok")]
        assert err.get("rid") is None       # unparseable: no rid to echo
        # and the connection still serves
        send_frame(sock, {"op": "capacity", "rid": 4})
        hdr, _ = recv_frame(sock)
        assert hdr["ok"] and hdr["rid"] == 4
    finally:
        sock.close()


def test_fatal_framing_error_still_drops_connection(srv):
    """A corrupt length prefix loses frame sync — the server must drop
    the connection, v2 or not."""
    kind, target = protocol.parse_addr(srv.addr)
    sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    sock.connect(target)
    sock.settimeout(10.0)
    try:
        assert _raw_hello(sock) == WIRE_V2
        sock.sendall(struct.pack("<I", (1 << 30) + 1))   # > MAX_FRAME
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline:
            got = recv_frame(sock)
            if got is None:
                break                        # server hung up: good
        else:
            pytest.fail("server kept the connection after frame desync")
    finally:
        sock.close()


# -- keepalive / timeouts -----------------------------------------------------

def test_keepalive_survives_idle_pipelined_connection(tmp_path):
    """The idle-connection bugfix: a quiet v2 connection outlives the
    server's conn_timeout because the channel pings under it."""
    s = PoolServer(DramPool(1 << 18), f"unix:{tmp_path}/ka.sock",
                   conn_timeout=1.0).start()
    try:
        dev = RemotePool(s.addr, timeout=Timeouts(control=5.0, data=10.0,
                                                  bulk=20.0, keepalive=0.3))
        r = _mkdata(dev)
        time.sleep(2.5)                      # > 2x the server conn_timeout
        assert bytes(dev.read(r.off, 8)) == bytes(range(8))
        assert dev.wire_stats()["pings"] > 0
        dev.close()
    finally:
        s.shutdown(close_device=True)


def test_v1_idle_connection_is_reaped(tmp_path):
    """Contrast cell: a v1 connection has no keepalive and the server's
    idle reaper fences it — the old (pre-fix) behaviour, now opt-in."""
    s = PoolServer(DramPool(1 << 18), f"unix:{tmp_path}/ka1.sock",
                   conn_timeout=1.0).start()
    try:
        dev = RemotePool(s.addr, timeout=20.0, wire=WIRE_V1)
        _mkdata(dev)
        time.sleep(2.5)
        with pytest.raises(PoolConnectionError):
            dev.ping()
    finally:
        s.shutdown(close_device=True)


def test_per_op_timeout_rejects_one_request_connection_survives(tmp_path):
    """A stalled reply trips PoolTimeoutError for THAT request only; the
    late reply is dropped by rid and the channel keeps working."""
    path = str(tmp_path / "stall.sock")
    lsock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    lsock.bind(path)
    lsock.listen(1)
    stop = threading.Event()

    def fake_server():
        conn, _ = lsock.accept()
        conn.settimeout(20.0)
        hdr, _ = recv_frame(conn)
        assert hdr["op"] == "hello"
        send_frame(conn, {"ok": True, "wire": WIRE_V2})
        while not stop.is_set():
            got = recv_frame(conn)
            if got is None:
                break
            h, _ = got
            if h["op"] == "capacity":
                time.sleep(1.2)              # stall past the op deadline
            if h["op"] == "close":
                send_frame(conn, {"ok": True, "rid": h.get("rid")})
                break
            send_frame(conn, {"ok": True, "capacity": 1 << 18,
                              "rid": h.get("rid")})
        conn.close()

    t = threading.Thread(target=fake_server, daemon=True)
    t.start()
    sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    sock.connect(path)
    chan = PoolChannel(sock, f"unix:{path}",
                       Timeouts(control=0.4, data=0.4, bulk=1.0,
                                keepalive=30.0))
    try:
        hdr, _ = chan.exchange({"op": "hello", "tenant": "t", "quota": 0,
                                "wire": WIRE_V2})
        chan.activate(int(hdr["wire"]))
        fut = chan.submit({"op": "capacity"})
        with pytest.raises(PoolTimeoutError):
            fut.result()
        # the stalled reply arrives late and is dropped by rid; the next
        # request gets its own rid and completes
        rh, _ = chan.request({"op": "ping"}, timeout=5.0)
        assert rh.get("ok")
        assert chan.stats()["timeouts"] == 1
        deadline = time.monotonic() + 5.0
        while chan.stats()["late_drops"] < 1 \
                and time.monotonic() < deadline:
            time.sleep(0.05)
        assert chan.stats()["late_drops"] >= 1
    finally:
        stop.set()
        chan.close()
        lsock.close()


# -- sharded routing over v2 --------------------------------------------------

def test_sharded_batch_routing_preserves_order(tmp_path):
    """read_batch across shards: one frame per node, results in request
    order."""
    servers = [PoolServer(DramPool(1 << 18),
                          f"unix:{tmp_path}/m{i}.sock").start()
               for i in range(2)]
    try:
        pool = make_pool("sharded",
                         shards=",".join(s.addr for s in servers),
                         timeout=20.0)
        a = PoolAllocator(pool)
        regs = []
        for dom in ("alpha", "beta", "gamma", "delta"):
            r = a.domain(dom).alloc("x", shape=(16,), dtype="uint8")
            pool.write(r.off, np.full(16, ord(dom[0]), np.uint8))
            regs.append((dom, r))
        owners = {pool.shard_of(r.off)[0].index for _, r in regs}
        assert owners == {0, 1}              # the batch really spans nodes
        got = pool.read_batch([(r.off, 16) for _, r in regs])
        for (dom, _), blob in zip(regs, got, strict=True):
            assert bytes(blob) == bytes([ord(dom[0])] * 16), dom
        pool.close()
    finally:
        for s in servers:
            s.shutdown(close_device=True)
