"""Wire protocol v2: version negotiation (mixed-version matrix), the typed
op registry as the ONE op table, pipelined request/response correlation
(fence-on-desync retired), scatter-gather batch frames, torn-frame isolation
mid-pipeline, keepalives on quiet connections, and per-op timeout classes."""
import socket
import struct
import threading
import time

import numpy as np
import pytest

from repro.pool import (DramPool, PmemPool, PoolAllocator,
                        PoolConnectionError, PoolError, PoolServer,
                        PoolTimeoutError, RemotePool, Timeouts, make_pool)
from repro.pool import protocol, remote, server, sharded
from repro.analysis.checker import RecycledBufferError
from repro.pool.protocol import (BIN_HDR_FLAG, WIRE_V1, WIRE_V2, WIRE_V3,
                                 BufferPool, PoolChannel, V3_CODECS,
                                 pack_v3_header, recv_frame, send_frame,
                                 unpack_v3_header, wire_from_env)


@pytest.fixture
def srv(tmp_path):
    s = PoolServer(DramPool(1 << 18), f"unix:{tmp_path}/pool.sock").start()
    yield s
    s.shutdown(close_device=True)


def _mkdata(dev, n=64, name="x", domain="d"):
    r = PoolAllocator(dev).domain(domain).alloc(name, shape=(n,),
                                                dtype="uint8")
    dev.write(r.off, np.arange(n, dtype=np.uint8))
    return r


# -- one op table -------------------------------------------------------------

def test_single_op_table():
    """Acceptance: remote.py, server.py, and sharded.py all dispatch off
    THE registry objects in protocol.py — no drifting copies."""
    assert remote.OPS is protocol.OPS
    assert remote.NMP_OPS is protocol.NMP_OPS
    assert server.OPS is protocol.OPS
    assert server.NMP_OPS is protocol.NMP_OPS
    assert sharded.NMP_OPS is protocol.NMP_OPS


def test_registry_covers_server_dispatch():
    """Every wire op the server dispatches has a registry descriptor (and
    nothing in the registry is undispatchable)."""
    for op, spec in protocol.OPS.items():
        assert spec.name == op
    for kind, spec in protocol.NMP_OPS.items():
        assert spec.kind == kind
        assert callable(spec.run)


# -- version negotiation ------------------------------------------------------

def test_v2_client_against_v1_server(tmp_path):
    s = PoolServer(DramPool(1 << 18), f"unix:{tmp_path}/v1.sock",
                   wire=WIRE_V1).start()
    try:
        dev = RemotePool(s.addr, timeout=20.0)     # asks for v3
        assert dev.wire == WIRE_V1
        r = _mkdata(dev)
        assert bytes(dev.read(r.off, 8)) == bytes(range(8))
        # the async surface degrades to completed depth-1 futures
        fut = dev.read_async(r.off, 8)
        assert bytes(fut.result()) == bytes(range(8))
        assert dev.read_batch([(r.off, 4), (r.off + 4, 4)]) == \
            [bytes(range(4)), bytes(range(4, 8))]
        dev.close()
    finally:
        s.shutdown(close_device=True)


def test_v1_client_against_v2_server(srv):
    dev = RemotePool(srv.addr, timeout=20.0, wire=WIRE_V1)
    assert dev.wire == WIRE_V1
    r = _mkdata(dev)
    assert bytes(dev.read(r.off, 8)) == bytes(range(8))
    dev.close()


def test_default_both_sides_negotiates_v3(srv):
    dev = RemotePool(srv.addr, timeout=20.0)
    assert dev.wire == WIRE_V3
    assert dev.wire_stats()["wire"] == WIRE_V3
    dev.close()


def test_v2_pinned_both_sides_stays_v2(srv):
    dev = RemotePool(srv.addr, timeout=20.0, wire=WIRE_V2)
    assert dev.wire == WIRE_V2
    assert dev.wire_stats()["wire"] == WIRE_V2
    r = _mkdata(dev)
    assert bytes(dev.read(r.off, 8)) == bytes(range(8))
    dev.close()


def test_wire_from_env(monkeypatch):
    monkeypatch.setenv("REPRO_POOL_WIRE", "v1")
    assert wire_from_env() == WIRE_V1
    monkeypatch.setenv("REPRO_POOL_WIRE", "2")
    assert wire_from_env() == WIRE_V2
    monkeypatch.setenv("REPRO_POOL_WIRE", "v3")
    assert wire_from_env() == WIRE_V3
    monkeypatch.setenv("REPRO_POOL_WIRE", "3")
    assert wire_from_env() == WIRE_V3
    monkeypatch.delenv("REPRO_POOL_WIRE")
    assert wire_from_env() == WIRE_V3


# -- pipelining ---------------------------------------------------------------

def _pools(tmp_path, servers):
    """The four backends behind one factory: (name, device) pairs."""
    out = [("dram", DramPool(1 << 18)),
           ("pmem", PmemPool(str(tmp_path / "p.img"), 1 << 18))]
    s1 = PoolServer(DramPool(1 << 18), f"unix:{tmp_path}/r.sock").start()
    servers.append(s1)
    out.append(("remote", RemotePool(s1.addr, timeout=20.0)))
    s2 = PoolServer(DramPool(1 << 18), f"unix:{tmp_path}/s0.sock").start()
    s3 = PoolServer(DramPool(1 << 18), f"unix:{tmp_path}/s1.sock").start()
    servers.extend([s2, s3])
    out.append(("sharded", make_pool("sharded",
                                     shards=f"{s2.addr},{s3.addr}",
                                     timeout=20.0)))
    return out


def test_pipeline_depth8_parity_all_backends(tmp_path):
    """Depth-8 pipelined reads return byte-identical results to
    sequential reads on every backend."""
    servers = []
    try:
        for name, dev in _pools(tmp_path, servers):
            r = _mkdata(dev, n=256)
            seq = [bytes(dev.read(r.off + 8 * i, 8)) for i in range(8)]
            futs = [dev.read_async(r.off + 8 * i, 8) for i in range(8)]
            piped = [bytes(f.result()) for f in futs]
            assert piped == seq, name
            batched = dev.read_batch([(r.off + 8 * i, 8)
                                      for i in range(8)])
            assert [bytes(b) for b in batched] == seq, name
            dev.close()
    finally:
        for s in servers:
            s.shutdown(close_device=True)


def test_pipelined_error_rejects_only_its_future(srv):
    """Fence-on-desync is retired: a failed pipelined op rejects ITS
    future; requests before and after it complete, and the connection
    keeps serving."""
    dev = RemotePool(srv.addr, timeout=20.0)
    assert dev.wire == WIRE_V3
    r = _mkdata(dev)
    good1 = dev.read_async(r.off, 8)
    bad = dev.read_async(1 << 29, 8)        # beyond capacity: typed error
    good2 = dev.read_async(r.off + 8, 8)
    assert bytes(good1.result()) == bytes(range(8))
    with pytest.raises(PoolError):
        bad.result()
    assert bytes(good2.result()) == bytes(range(8, 16))
    assert not dev.closed                   # the connection survived
    assert bytes(dev.read(r.off, 4)) == bytes(range(4))
    dev.close()


def test_batch_frame_is_one_round_trip(srv):
    dev = RemotePool(srv.addr, timeout=20.0)
    r = _mkdata(dev, n=128)
    calls = []
    orig = dev._request

    def counting(hdr, body=b""):
        calls.append(hdr["op"])
        return orig(hdr, body)

    dev._request = counting
    try:
        got = dev.read_batch([(r.off + i, 1) for i in range(16)])
    finally:
        dev._request = orig
    assert calls == ["batch"]
    assert b"".join(bytes(b) for b in got) == bytes(range(16))
    dev.close()


# -- torn frames --------------------------------------------------------------

def _raw_hello(sock, wire=WIRE_V2):
    send_frame(sock, {"op": "hello", "tenant": "torn", "quota": 0,
                      "wire": wire})
    hdr, _ = recv_frame(sock)
    assert hdr.get("ok"), hdr
    return int(hdr.get("wire", WIRE_V1))


def test_torn_frame_mid_pipeline_rejects_exactly_one(srv):
    """A frame whose header fails to parse (stream still at a frame
    boundary) produces ONE error reply; requests around it succeed on the
    same connection."""
    kind, target = protocol.parse_addr(srv.addr)
    sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    sock.connect(target)
    sock.settimeout(10.0)
    try:
        assert _raw_hello(sock) == WIRE_V2
        send_frame(sock, {"op": "capacity", "rid": 1})
        garbage = b"\x00not json at all\xff"
        sock.sendall(struct.pack("<I", 4 + len(garbage))
                     + struct.pack("<I", len(garbage)) + garbage)
        send_frame(sock, {"op": "capacity", "rid": 3})
        replies = [recv_frame(sock)[0] for _ in range(3)]
        by_rid = {h.get("rid"): h for h in replies}
        assert by_rid[1]["ok"] and by_rid[3]["ok"]
        (err,) = [h for h in replies if not h.get("ok")]
        assert err.get("rid") is None       # unparseable: no rid to echo
        # and the connection still serves
        send_frame(sock, {"op": "capacity", "rid": 4})
        hdr, _ = recv_frame(sock)
        assert hdr["ok"] and hdr["rid"] == 4
    finally:
        sock.close()


def test_fatal_framing_error_still_drops_connection(srv):
    """A corrupt length prefix loses frame sync — the server must drop
    the connection, v2 or not."""
    kind, target = protocol.parse_addr(srv.addr)
    sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    sock.connect(target)
    sock.settimeout(10.0)
    try:
        assert _raw_hello(sock) == WIRE_V2
        sock.sendall(struct.pack("<I", (1 << 30) + 1))   # > MAX_FRAME
        deadline = time.monotonic() + 10.0
        while time.monotonic() < deadline:
            got = recv_frame(sock)
            if got is None:
                break                        # server hung up: good
        else:
            pytest.fail("server kept the connection after frame desync")
    finally:
        sock.close()


# -- keepalive / timeouts -----------------------------------------------------

def test_keepalive_survives_idle_pipelined_connection(tmp_path):
    """The idle-connection bugfix: a quiet v2 connection outlives the
    server's conn_timeout because the channel pings under it."""
    s = PoolServer(DramPool(1 << 18), f"unix:{tmp_path}/ka.sock",
                   conn_timeout=1.0).start()
    try:
        dev = RemotePool(s.addr, timeout=Timeouts(control=5.0, data=10.0,
                                                  bulk=20.0, keepalive=0.3))
        r = _mkdata(dev)
        time.sleep(2.5)                      # > 2x the server conn_timeout
        assert bytes(dev.read(r.off, 8)) == bytes(range(8))
        assert dev.wire_stats()["pings"] > 0
        dev.close()
    finally:
        s.shutdown(close_device=True)


def test_v1_idle_connection_is_reaped(tmp_path):
    """Contrast cell: a v1 connection has no keepalive and the server's
    idle reaper fences it — the old (pre-fix) behaviour, now opt-in."""
    s = PoolServer(DramPool(1 << 18), f"unix:{tmp_path}/ka1.sock",
                   conn_timeout=1.0).start()
    try:
        dev = RemotePool(s.addr, timeout=20.0, wire=WIRE_V1)
        _mkdata(dev)
        time.sleep(2.5)
        with pytest.raises(PoolConnectionError):
            dev.ping()
    finally:
        s.shutdown(close_device=True)


def test_per_op_timeout_rejects_one_request_connection_survives(tmp_path):
    """A stalled reply trips PoolTimeoutError for THAT request only; the
    late reply is dropped by rid and the channel keeps working."""
    path = str(tmp_path / "stall.sock")
    lsock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    lsock.bind(path)
    lsock.listen(1)
    stop = threading.Event()

    def fake_server():
        conn, _ = lsock.accept()
        conn.settimeout(20.0)
        hdr, _ = recv_frame(conn)
        assert hdr["op"] == "hello"
        send_frame(conn, {"ok": True, "wire": WIRE_V2})
        while not stop.is_set():
            got = recv_frame(conn)
            if got is None:
                break
            h, _ = got
            if h["op"] == "capacity":
                time.sleep(1.2)              # stall past the op deadline
            if h["op"] == "close":
                send_frame(conn, {"ok": True, "rid": h.get("rid")})
                break
            send_frame(conn, {"ok": True, "capacity": 1 << 18,
                              "rid": h.get("rid")})
        conn.close()

    t = threading.Thread(target=fake_server, daemon=True)
    t.start()
    sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    sock.connect(path)
    chan = PoolChannel(sock, f"unix:{path}",
                       Timeouts(control=0.4, data=0.4, bulk=1.0,
                                keepalive=30.0))
    try:
        hdr, _ = chan.exchange({"op": "hello", "tenant": "t", "quota": 0,
                                "wire": WIRE_V2})
        chan.activate(int(hdr["wire"]))
        fut = chan.submit({"op": "capacity"})
        with pytest.raises(PoolTimeoutError):
            fut.result()
        # the stalled reply arrives late and is dropped by rid; the next
        # request gets its own rid and completes
        rh, _ = chan.request({"op": "ping"}, timeout=5.0)
        assert rh.get("ok")
        assert chan.stats()["timeouts"] == 1
        deadline = time.monotonic() + 5.0
        while chan.stats()["late_drops"] < 1 \
                and time.monotonic() < deadline:
            time.sleep(0.05)
        assert chan.stats()["late_drops"] >= 1
    finally:
        stop.set()
        chan.close()
        lsock.close()


# -- sharded routing over v2 --------------------------------------------------

def test_sharded_batch_routing_preserves_order(tmp_path):
    """read_batch across shards: one frame per node, results in request
    order."""
    servers = [PoolServer(DramPool(1 << 18),
                          f"unix:{tmp_path}/m{i}.sock").start()
               for i in range(2)]
    try:
        pool = make_pool("sharded",
                         shards=",".join(s.addr for s in servers),
                         timeout=20.0)
        a = PoolAllocator(pool)
        regs = []
        for dom in ("alpha", "beta", "gamma", "delta"):
            r = a.domain(dom).alloc("x", shape=(16,), dtype="uint8")
            pool.write(r.off, np.full(16, ord(dom[0]), np.uint8))
            regs.append((dom, r))
        owners = {pool.shard_of(r.off)[0].index for _, r in regs}
        assert owners == {0, 1}              # the batch really spans nodes
        got = pool.read_batch([(r.off, 16) for _, r in regs])
        for (dom, _), blob in zip(regs, got, strict=True):
            assert bytes(blob) == bytes([ord(dom[0])] * 16), dom
        pool.close()
    finally:
        for s in servers:
            s.shutdown(close_device=True)


# -- wire v3: binary headers, zero-copy bodies, pooled buffers ----------------

def test_v3_client_against_v2_server(tmp_path):
    """Interop down: a default (v3) client lands on v2 against a v2-pinned
    server and round-trips data."""
    s = PoolServer(DramPool(1 << 18), f"unix:{tmp_path}/v2.sock",
                   wire=WIRE_V2).start()
    try:
        dev = RemotePool(s.addr, timeout=20.0)
        assert dev.wire == WIRE_V2
        r = _mkdata(dev)
        assert bytes(dev.read(r.off, 8)) == bytes(range(8))
        fut = dev.read_async(r.off, 8)
        assert bytes(fut.result()) == bytes(range(8))
        dev.close()
    finally:
        s.shutdown(close_device=True)


def test_v3_binary_header_roundtrip_over_the_wire(srv):
    """A default connection really uses binary headers: data ops succeed
    end to end and every data-class op name has a codec."""
    dev = RemotePool(srv.addr, timeout=20.0)
    assert dev.wire == WIRE_V3
    r = _mkdata(dev, n=128)
    dev.write(r.off, np.arange(128, dtype=np.uint8)[::-1].copy())
    assert bytes(dev.read(r.off, 4)) == bytes([127, 126, 125, 124])
    got = dev.read_batch([(r.off, 4), (r.off + 4, 4)])
    assert bytes(got[1]) == bytes([123, 122, 121, 120])
    for name in ("read", "write", "gather", "bag_gather",
                 "undo_log_append", "slot_headers", "region_export",
                 "region_import", "blob_put"):
        assert name in V3_CODECS, name
    dev.close()


def test_v3_data_path_copies_zero_bytes(srv):
    """The acceptance gate: on a v3 connection neither side copies data
    bytes — client and server bytes_copied stay 0 while data_frames
    count, for read, write, read_batch and nmp gather alike."""
    dev = RemotePool(srv.addr, timeout=20.0, tenant="zc")
    assert dev.wire == WIRE_V3
    a = PoolAllocator(dev)
    r = a.domain("zc").alloc("m", shape=(16, 8), dtype="float32")
    dev.write(r.off, np.arange(128, dtype=np.float32).reshape(16, 8))
    assert bytes(dev.read(r.off, 16)) == \
        np.arange(4, dtype=np.float32).tobytes()
    dev.read_batch([(r.off, 8), (r.off + 8, 8)])
    rows = dev.nmp("gather", r, idx=np.array([1, 3]))
    assert rows.shape == (2, 8)
    st = dev.wire_stats()
    assert st["data_frames"] >= 4
    assert st["bytes_copied"] == 0
    assert st["recv_pool"]["acquired"] > 0
    m = srv.tenants["zc"].metrics
    assert m.data_frames >= 4
    assert m.bytes_copied == 0
    dev.close()
    # contrast cell: the same ops over a pinned v2 connection DO copy
    dev2 = RemotePool(srv.addr, timeout=20.0, tenant="zc2", wire=WIRE_V2)
    r2 = PoolAllocator(dev2).domain("zc2").alloc("m", shape=(64,),
                                                 dtype="uint8")
    dev2.write(r2.off, np.arange(64, dtype=np.uint8))
    bytes(dev2.read(r2.off, 64))
    st2 = dev2.wire_stats()
    assert st2["bytes_copied"] > 0
    assert srv.tenants["zc2"].metrics.bytes_copied > 0
    dev2.close()


def test_torn_binary_frame_mid_pipeline_rejects_exactly_one(srv):
    """The binary twin of the JSON torn-frame cell: a BIN_HDR_FLAG frame
    whose header fails to decode produces ONE no-rid error reply; the
    requests around it succeed and the connection keeps serving."""
    kind, target = protocol.parse_addr(srv.addr)
    sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    sock.connect(target)
    sock.settimeout(10.0)
    try:
        assert _raw_hello(sock, wire=WIRE_V3) == WIRE_V3
        send_frame(sock, {"op": "capacity", "rid": 1})
        bh = struct.pack("<HHQ", 127, 0, 2)   # unknown binary op code
        sock.sendall(struct.pack("<II", 4 + len(bh),
                                 len(bh) | BIN_HDR_FLAG) + bh)
        send_frame(sock, {"op": "capacity", "rid": 3})
        replies = [recv_frame(sock)[0] for _ in range(3)]
        by_rid = {h.get("rid"): h for h in replies}
        assert by_rid[1]["ok"] and by_rid[3]["ok"]
        (err,) = [h for h in replies if not h.get("ok")]
        assert err.get("rid") is None
        send_frame(sock, {"op": "capacity", "rid": 4})
        hdr, _ = recv_frame(sock)
        assert hdr["ok"] and hdr["rid"] == 4
    finally:
        sock.close()


def test_v3_codec_pack_unpack_roundtrip():
    """pack_v3_header -> unpack_v3_header is the identity on canonical
    data-op headers, and falls back (None) on anything else."""
    hdrs = [
        {"op": "read", "off": 4096, "nbytes": 65536, "rid": 7},
        {"op": "write", "off": 0, "rid": 1},
        {"op": "nmp", "kind": "gather", "rid": 9,
         "region": {"off": 64, "nbytes": 512, "dtype": "float32",
                    "shape": [16, 8]},
         "combine": "sum", "point": None},
    ]
    for hdr in hdrs:
        bh = pack_v3_header(hdr)
        assert bh is not None, hdr
        back = unpack_v3_header(memoryview(bh))
        for k, v in hdr.items():
            assert back[k] == v, (k, hdr)
    assert pack_v3_header({"op": "capacity", "rid": 1}) is None
    assert pack_v3_header({"op": "read", "off": 0, "nbytes": 8,
                           "weird": 1}) is None


def test_buffer_pool_reuse_after_release_is_typed_violation():
    """Rule L drill: a loan's view dies with a RecycledBufferError once
    the pool recycles the buffer; detach() keeps views alive forever;
    double release is a no-op."""
    pool = BufferPool(max_free=4)
    loan = pool.acquire(64)
    v = loan.view()
    v[:4] = b"abcd"
    assert bytes(loan.view()[:4]) == b"abcd"
    loan.release()
    loan.release()                           # double release: no-op
    again = pool.acquire(32)                 # recycles the same buffer
    assert pool.stats()["reused"] == 1
    with pytest.raises(RecycledBufferError):
        loan.view()
    # detached loans survive recycling of everything else
    keeper = pool.acquire(16)
    kv_src = keeper.view()
    kv_src[:2] = b"ok"
    keeper.detach()
    keeper.release()                         # no-op on a detached loan
    again.release()
    for _ in range(8):
        pool.acquire(16).release()
    assert bytes(keeper.view()[:2]) == b"ok"


def test_channel_recycles_recv_buffers_across_requests(srv):
    """Ack frames return their loaned buffers to the channel pool, so a
    write-heavy stream reuses buffers instead of allocating per frame."""
    dev = RemotePool(srv.addr, timeout=20.0)
    r = _mkdata(dev)
    blob = np.arange(64, dtype=np.uint8)
    for _ in range(16):
        dev.write(r.off, blob)
    st = dev.wire_stats()["recv_pool"]
    assert st["reused"] > 0, st
    dev.close()


def test_bulk_timeout_scales_with_payload():
    """Satellite: the flat bulk deadline is the FLOOR; payload-heavy bulk
    ops get transfer time at the modeled link floor on top."""
    t = Timeouts(control=5.0, data=10.0, bulk=30.0, keepalive=0.0)
    flat = t.for_hdr({"op": "nmp", "kind": "region_export",
                      "region": {"off": 0, "nbytes": 1024}})
    assert flat == pytest.approx(30.0, abs=1e-3)
    big = t.for_hdr({"op": "nmp", "kind": "region_export",
                     "region": {"off": 0, "nbytes": 40 * (1 << 20)}})
    assert big == pytest.approx(30.0 + 40 * (1 << 20) / t.BULK_BW_FLOOR)
    assert big > flat
    # request-body side (import/blob_put) scales through nbytes
    up = t.for_hdr({"op": "nmp", "kind": "blob_put"},
                   nbytes=80 * (1 << 20))
    assert up > t.for_hdr({"op": "nmp", "kind": "blob_put"}, nbytes=0)
    # data/control classes stay flat no matter the size
    assert t.for_hdr({"op": "read"}, nbytes=1 << 30) == 10.0
