"""Chunked attention vs full-softmax oracle; decode attention; MoE local
path; optimizer math."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need the optional dev dep
from hypothesis import given, settings, strategies as st

from repro.kernels.ref import flash_attention_ref
from repro.models import layers


@pytest.mark.parametrize("B,S,Hq,Hkv,D,causal", [
    (2, 64, 4, 2, 16, True), (1, 96, 4, 4, 32, False),
    (2, 33, 6, 2, 16, True), (2, 64, 8, 1, 16, True)])
def test_chunked_attention_vs_ref(rng, B, S, Hq, Hkv, D, causal):
    q = jnp.asarray(rng.standard_normal((B, S, Hq, D)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((B, S, Hkv, D)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((B, S, Hkv, D)).astype(np.float32))
    out = layers.chunked_attention(q, k, v, causal=causal, q_chunk=16)
    G = Hq // Hkv
    kf = jnp.repeat(k, G, axis=2)
    vf = jnp.repeat(v, G, axis=2)
    want = flash_attention_ref(q, kf, vf, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_vs_full(rng):
    B, S, Hq, Hkv, D = 2, 32, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((B, 1, Hq, D)).astype(np.float32))
    kc = jnp.asarray(rng.standard_normal((B, S, Hkv, D)).astype(np.float32))
    vc = jnp.asarray(rng.standard_normal((B, S, Hkv, D)).astype(np.float32))
    kv_len = 20
    out = layers.decode_attention(q, kc, vc, kv_len)
    G = Hq // Hkv
    want = flash_attention_ref(
        q, jnp.repeat(kc[:, :kv_len], G, axis=2),
        jnp.repeat(vc[:, :kv_len], G, axis=2), causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want[:, -1:]),
                               rtol=2e-5, atol=2e-5)


def test_context_parallel_decode_single_device(rng):
    """CP decode on a 1-device mesh must equal plain decode attention."""
    from repro.distributed import sharding
    from repro.distributed.context_parallel import decode_attention_cp
    from repro.launch.mesh import make_local_mesh
    mesh = make_local_mesh(model_parallel=1)
    B, S, Hq, Hkv, D = 2, 16, 4, 2, 8
    q = jnp.asarray(rng.standard_normal((B, 1, Hq, D)).astype(np.float32))
    kc = jnp.asarray(rng.standard_normal((B, S, Hkv, D)).astype(np.float32))
    vc = jnp.asarray(rng.standard_normal((B, S, Hkv, D)).astype(np.float32))
    nk = jnp.asarray(rng.standard_normal((B, 1, Hkv, D)).astype(np.float32))
    nv = jnp.asarray(rng.standard_normal((B, 1, Hkv, D)).astype(np.float32))
    pos = 7
    with sharding.use_sharding(mesh, {"batch": None, "cache_seq": "model"}):
        out, kc2, vc2 = jax.jit(decode_attention_cp)(q, kc, vc, nk, nv,
                                                     jnp.asarray(pos))
    kc_ref = kc.at[:, pos].set(nk[:, 0])
    vc_ref = vc.at[:, pos].set(nv[:, 0])
    want = layers.decode_attention(q, kc_ref, vc_ref, pos + 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_array_equal(np.asarray(kc2), np.asarray(kc_ref))


def test_moe_ep_matches_local(rng):
    """shard_map EP path on a 1x1 mesh == plain local path."""
    from repro.configs import get_arch
    from repro.distributed import sharding
    from repro.launch.mesh import make_local_mesh
    from repro.models import moe
    b = get_arch("qwen3-moe-235b-a22b", smoke=True)
    cfg = b.model
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.standard_normal((2, 8, cfg.d_model))
                    .astype(np.float32))
    out_local, aux_local = moe.moe_fwd(p, cfg, x)
    mesh = make_local_mesh(model_parallel=1)
    with sharding.use_sharding(mesh, {"batch": None, "seq": None}):
        out_ep, aux_ep = jax.jit(lambda p, x: moe.moe_fwd(p, cfg, x))(p, x)
    np.testing.assert_allclose(np.asarray(out_local), np.asarray(out_ep),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(float(aux_local), float(aux_ep), rtol=1e-5)


def test_moe_gradients_flow(rng):
    from repro.configs import get_arch
    from repro.models import moe
    b = get_arch("qwen3-moe-235b-a22b", smoke=True)
    cfg = b.model
    p = moe.init_moe(jax.random.PRNGKey(0), cfg)
    x = jnp.asarray(rng.standard_normal((2, 4, cfg.d_model))
                    .astype(np.float32))
    def loss(p, x):
        out, aux = moe.moe_fwd(p, cfg, x)
        return jnp.sum(out ** 2) + 0.01 * aux
    g = jax.grad(loss)(p, x)
    for path in ("router", "wi", "wg", "wo"):
        assert float(jnp.abs(g[path]).sum()) > 0, path


def test_optimizers_math(rng):
    from repro.optim import optimizers as opt
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, 0.5])}
    o = opt.sgd(0.1)
    upd, _ = o.update(g, o.init(p), p)
    np.testing.assert_allclose(np.asarray(upd["w"]), [-0.05, -0.05])

    o = opt.adamw(1e-2, 0.9, 0.999)
    st = o.init(p)
    upd, st = o.update(g, st, p)
    # first step: m_hat = g, v_hat = g^2 -> update = -lr * sign-ish
    np.testing.assert_allclose(np.asarray(upd["w"]),
                               [-1e-2 * 0.5 / (0.5 + 1e-8)] * 2, rtol=1e-4)

    o = opt.rowwise_adagrad(0.1)
    t = {"t": jnp.ones((4, 2))}
    gt = {"t": jnp.ones((4, 2)) * 2.0}
    st = o.init(t)
    upd, st2 = o.update(gt, st, t)
    # acc = mean(g^2) per row = 4 -> update = -0.1*2/2 = -0.1
    np.testing.assert_allclose(np.asarray(upd["t"]),
                               np.full((4, 2), -0.1), rtol=1e-5)


@settings(deadline=None, max_examples=8)
@given(seed=st.integers(0, 100), s=st.integers(3, 40))
def test_property_chunked_attention(seed, s):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.standard_normal((1, s, 2, 8)).astype(np.float32))
    k = jnp.asarray(rng.standard_normal((1, s, 2, 8)).astype(np.float32))
    v = jnp.asarray(rng.standard_normal((1, s, 2, 8)).astype(np.float32))
    out = layers.chunked_attention(q, k, v, causal=True, q_chunk=8)
    want = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=3e-5, atol=3e-5)
